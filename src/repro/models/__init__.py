from repro.models.model import (  # noqa: F401
    build_model,
    init_cache,
    init_paged_cache,
    init_params,
    supports_paged_cache,
)
