"""Attention in pure JAX, shaped for honest HLO cost accounting.

Two execution regimes:

* **blockwise_attention** — train / prefill. Flash-style online-softmax over
  (q-tile, kv-tile) pairs: q tiles as a Python loop, kv tiles as a ``lax.scan``
  (peak temp = one tile's working set), each q-tile checkpointed so the
  backward recomputes attention tile-by-tile (flash-style). Causal and
  sliding-window structure prunes kv ranges at trace time, so the FLOPs are
  the true banded/causal FLOPs, not a masked dense S². For the roofline pass
  ``unroll=True`` inlines the kv loop — XLA's cost analysis counts a while
  body once, so exact accounting needs the unrolled form.
* **decode_attention / mla_decode_attention** — single-token decode against a
  (possibly sequence-sharded) KV cache; einsum formulation whose softmax
  reductions GSPMD turns into small all-reduces (flash-decode semantics).

GQA is computed without materializing repeated KV heads: q is grouped
``[B, S, Hkv, G, D]`` and all einsums contract against ``[B, S, Hkv, D]``.

On real TPU the Pallas kernels in ``repro.kernels`` replace these paths; the
``ref.py`` oracles there call into this module.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _softcap(s: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0.0:
        return cap * jnp.tanh(s / cap)
    return s


def tile_pairs(
    n_q: int,
    n_k: int,
    *,
    block_q: int,
    block_k: int,
    causal: bool,
    window: int,
    q_offset: int,
) -> list:
    """Statically enumerate (i, j) tile pairs that contain any unmasked entry.

    q tile i covers query positions [q_offset + i*bq, q_offset + (i+1)*bq);
    kv tile j covers key positions [j*bk, (j+1)*bk).
    """
    pairs = []
    for i in range(n_q):
        q_lo = q_offset + i * block_q
        q_hi = q_offset + (i + 1) * block_q - 1
        for j in range(n_k):
            k_lo = j * block_k
            k_hi = (j + 1) * block_k - 1
            if causal and k_lo > q_hi:
                continue  # tile entirely above the diagonal
            if window and window > 0 and k_hi < q_lo - window + 1:
                continue  # tile entirely outside the sliding window
            pairs.append((i, j))
    return pairs


def blockwise_attention(
    q: jnp.ndarray,  # [B, Sq, Hkv, G, Dh]
    k: jnp.ndarray,  # [B, Sk, Hkv, Dh]
    v: jnp.ndarray,  # [B, Sk, Hkv, Dv]
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    kv_valid_len: Optional[jnp.ndarray] = None,  # [B] or scalar; None => all
    block_q: int = 512,
    block_k: int = 512,
    unroll: bool = False,
) -> jnp.ndarray:
    """Returns [B, Sq, Hkv, G, Dv]. fp32 softmax state, MXU-dtype matmuls.

    The kv-tile loop is a ``lax.scan`` (so peak temp memory is one tile's
    working set — XLA CPU deletes ``optimization_barrier`` and otherwise keeps
    every tile's scores live, O(S^2) temp), and each q-tile is wrapped in
    ``jax.checkpoint`` so the backward pass recomputes attention tile-by-tile
    (flash-attention-style recompute). ``unroll=True`` inlines the loop for
    the roofline pass, where XLA's cost analysis must see every tile matmul
    (while bodies are counted once).
    """
    B, Sq, Hkv, G, Dh = q.shape
    _, Sk, _, Dv = v.shape
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    if Sq % block_q or Sk % block_k:
        # Pad to block multiples; padded keys are masked out, padded query rows
        # are sliced off. Keeps the static-tile machinery simple for odd
        # engine-side shapes (the assigned dry-run shapes are all aligned).
        pq = (-Sq) % block_q
        pk = (-Sk) % block_k
        qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        vl = kv_valid_len if kv_valid_len is not None else Sk
        out = blockwise_attention(
            qp, kp, vp, scale=scale, causal=causal, window=window,
            softcap=softcap, q_offset=q_offset, kv_valid_len=vl,
            block_q=block_q, block_k=block_k, unroll=unroll,
        )
        return out[:, :Sq]
    n_q, n_k = Sq // block_q, Sk // block_k

    qt = q.reshape(B, n_q, block_q, Hkv, G, Dh)
    kt = k.reshape(B, n_k, block_k, Hkv, Dh)
    vt = v.reshape(B, n_k, block_k, Hkv, Dv)

    def kv_ranges(i: int):
        """Contiguous kv-tile range [lo, hi) q-tile i attends to."""
        q_lo = q_offset + i * block_q
        q_hi = q_offset + (i + 1) * block_q - 1
        hi = n_k if not causal else min(n_k, q_hi // block_k + 1)
        lo = 0
        if window and window > 0:
            lo = max(0, (q_lo - window + 1) // block_k)
        return lo, hi

    def _fully_visible(i: int, j: int) -> bool:
        """Every q row of tile i sees every k of tile j (mask-free tile)."""
        q_lo = q_offset + i * block_q
        q_hi = q_offset + (i + 1) * block_q - 1
        k_lo, k_hi = j * block_k, (j + 1) * block_k - 1
        if causal and k_hi > q_lo:
            return False
        if window and window > 0 and k_lo < q_hi - window + 1:
            return False
        return True

    def tile_update(carry, k_j, v_j, j, q_i, q_pos, need_mask: bool):
        m, l, acc = carry
        # q was pre-scaled once per q-tile; scoring here is a bare matmul.
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", q_i, k_j, preferred_element_type=jnp.float32)
        s = _softcap(s, softcap)
        if need_mask or kv_valid_len is not None:
            k_pos = j * block_k + jnp.arange(block_k)
            mask = jnp.ones((block_q, block_k), bool)
            if need_mask and causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if need_mask and window and window > 0:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            mask_b = jnp.broadcast_to(mask, (B, 1, 1, block_q, block_k))
            if kv_valid_len is not None:
                vl = jnp.asarray(kv_valid_len).reshape(-1, 1, 1, 1, 1)
                mask_b = mask_b & (k_pos[None, None, None, None, :] < vl)
            s = jnp.where(mask_b, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(v_j.dtype), v_j,
            preferred_element_type=jnp.float32,
        )
        return m_new, l, acc

    def one_q_tile(q_i, ks_i, vs_i, i: int, lo: int):
        q_pos = q_offset + i * block_q + jnp.arange(block_q)
        # fold the softmax scale into q once per q tile ([bq, D] elementwise)
        # instead of into every [bq, bk] score tile.
        q_i = (q_i.astype(jnp.float32) * scale).astype(q_i.dtype)
        m0 = jnp.full((B, Hkv, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, block_q, Dv), jnp.float32)
        n_i = ks_i.shape[1]
        # split the kv range into mask-free interior tiles (scanned) and
        # boundary tiles (diagonal / window edge) that need position masks.
        interior = [t for t in range(n_i) if _fully_visible(i, lo + t)]
        boundary = [t for t in range(n_i) if t not in interior]
        carry = (m0, l0, a0)
        if unroll:
            for t in interior:
                carry = tile_update(carry, ks_i[:, t], vs_i[:, t], lo + t,
                                    q_i, q_pos, need_mask=False)
        elif interior:
            # interior tiles are contiguous [min, max] by construction
            t0, t1 = interior[0], interior[-1] + 1

            def step(c, inp):
                k_j, v_j, j = inp
                return tile_update(c, k_j, v_j, j, q_i, q_pos,
                                   need_mask=False), None
            xs = (ks_i[:, t0:t1].transpose(1, 0, 2, 3, 4),
                  vs_i[:, t0:t1].transpose(1, 0, 2, 3, 4),
                  lo + t0 + jnp.arange(t1 - t0))
            carry, _ = jax.lax.scan(step, carry, xs)
        for t in boundary:
            carry = tile_update(carry, ks_i[:, t], vs_i[:, t], lo + t,
                                q_i, q_pos, need_mask=True)
        m, l, acc = carry
        out_i = acc / jnp.maximum(l, 1e-30)[..., None]     # [B,Hkv,G,bq,Dv]
        return out_i.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    out_tiles = []
    for i in range(n_q):
        lo, hi = kv_ranges(i)
        fn = one_q_tile if unroll else jax.checkpoint(
            one_q_tile, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(3, 4))
        out_tiles.append(fn(qt[:, i], kt[:, lo:hi], vt[:, lo:hi], i, lo))
    out = jnp.concatenate(out_tiles, axis=1) if len(out_tiles) > 1 else out_tiles[0]
    return out


def decode_attention(
    q: jnp.ndarray,        # [B, Hkv, G, Dh]
    k_cache: jnp.ndarray,  # [B, S, Hkv, Dh]
    v_cache: jnp.ndarray,  # [B, S, Hkv, Dv]
    length: jnp.ndarray,   # scalar or [B]: number of valid cache entries
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """One-token attention read of the cache. Returns [B, Hkv, G, Dv].

    Softmax reductions over the (possibly sharded) S axis lower to partial
    reductions + tiny all-reduces under GSPMD — flash-decode by construction.
    """
    B, S, Hkv, Dh = k_cache.shape
    s = jnp.einsum("bhgd,bshd->bhgs", q, k_cache, preferred_element_type=jnp.float32)
    s = _softcap(s * scale, softcap)
    k_pos = jnp.arange(S)
    vl = jnp.asarray(length).reshape(-1, 1, 1, 1) if jnp.ndim(length) else length
    mask = k_pos[None, None, None, :] < vl
    if window and window > 0:
        mask = mask & (k_pos[None, None, None, :] >= vl - window)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = (p / jnp.maximum(l, 1e-30)).astype(v_cache.dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache, preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def mla_decode_attention(
    q_lat: jnp.ndarray,   # [B, H, R]   (q_nope absorbed through W_UK)
    q_rope: jnp.ndarray,  # [B, H, Dr]
    ckv: jnp.ndarray,     # [B, S, R]   compressed KV latent cache
    k_rope: jnp.ndarray,  # [B, S, Dr]  shared rope key cache
    length: jnp.ndarray,
    *,
    scale: float,
) -> jnp.ndarray:
    """Weight-absorbed MLA decode. Returns latent output [B, H, R] (to be
    expanded through W_UV by the caller)."""
    B, S, R = ckv.shape
    s = jnp.einsum("bhr,bsr->bhs", q_lat, ckv, preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhd,bsd->bhs", q_rope, k_rope, preferred_element_type=jnp.float32)
    s = s * scale
    k_pos = jnp.arange(S)
    vl = jnp.asarray(length).reshape(-1, 1, 1) if jnp.ndim(length) else length
    s = jnp.where(k_pos[None, None, :] < vl, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhs,bsr->bhr", p.astype(ckv.dtype), ckv, preferred_element_type=jnp.float32)
    return out.astype(q_lat.dtype)


def gather_pages(
    pages: jnp.ndarray,         # [Hkv, P, ps, D] physical pages
    block_tables: jnp.ndarray,  # [R, n] int32 logical->physical page map
) -> jnp.ndarray:
    """Materialize each row's logical KV view: returns [R, n*ps, Hkv, D].

    The result has exactly the contiguous ``[B, S, Hkv, D]`` layout the
    chunk-attention path consumes; positions past a row's valid length are
    masked by the caller (they may alias freed or trash pages). The TPU
    serving hot path no longer materializes this buffer (the
    ``paged_prefill_attention`` kernel streams pages straight from the block
    table); it remains the gather for the CPU jnp oracles and tests.
    """
    g = pages[:, block_tables]                      # [Hkv, R, n, ps, D]
    Hkv, R, n, ps, D = g.shape
    return g.transpose(1, 2, 3, 0, 4).reshape(R, n * ps, Hkv, D)


def write_pages(
    pages: jnp.ndarray,  # [Hkv, P, ps, D]
    new: jnp.ndarray,    # [R, L, Hkv, D] new keys/values (row-major tokens)
    slots: jnp.ndarray,  # [R*L] int32 flat destinations (page*ps + offset)
) -> jnp.ndarray:
    """Scatter new tokens into physical pages via a vLLM-style slot mapping.

    Padding tokens must be routed to a trash slot by the caller (the engine
    reserves the last physical page for this); duplicate trash indices are
    harmless — last write wins and the page is never read.
    """
    Hkv, P, ps, D = pages.shape
    flat = pages.reshape(Hkv, P * ps, D)
    upd = new.reshape(-1, Hkv, D).transpose(1, 0, 2)   # [Hkv, R*L, D]
    flat = flat.at[:, slots].set(upd.astype(flat.dtype), mode="drop",
                                 unique_indices=False)
    return flat.reshape(Hkv, P, ps, D)


def write_pages_fused(
    kv_pages: jnp.ndarray,  # [Hkv, P, 2, ps, D] fused head-interleaved pool
    k_new: jnp.ndarray,     # [R, L, Hkv, D] new keys (row-major tokens)
    v_new: jnp.ndarray,     # [R, L, Hkv, D] new values
    slots: jnp.ndarray,     # [R*L] int32 flat destinations (page*ps + offset)
) -> jnp.ndarray:
    """Scatter K and V into the fused pool with ONE gather-scatter.

    Token slot ``p*ps + o`` lands at flat index ``p*(2*ps) + o`` for K and
    ``p*(2*ps) + ps + o`` for V (K plane then V plane inside each page), so
    a single indexed update covers both — one scatter kernel per layer where
    the split layout dispatched two. Trash-slot semantics match
    :func:`write_pages`."""
    Hkv, P, two, ps, D = kv_pages.shape
    flat = kv_pages.reshape(Hkv, P * two * ps, D)
    k_idx = (slots // ps) * (two * ps) + slots % ps
    idx = jnp.concatenate([k_idx, k_idx + ps])
    upd = jnp.concatenate([k_new.reshape(-1, Hkv, D),
                           v_new.reshape(-1, Hkv, D)]).transpose(1, 0, 2)
    flat = flat.at[:, idx].set(upd.astype(flat.dtype), mode="drop",
                               unique_indices=False)
    return flat.reshape(Hkv, P, two, ps, D)


def update_kv_cache(
    cache: jnp.ndarray,  # [B, S, ...]
    new: jnp.ndarray,    # [B, n, ...]
    pos,                 # scalar int: uniform write offset
) -> jnp.ndarray:
    """Uniform-position cache write (dry-run / lockstep decode fast path)."""
    idx = (0, pos) + (0,) * (cache.ndim - 2)
    return jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype), idx)


def update_kv_cache_ragged(
    cache: jnp.ndarray,  # [B, S, ...]
    new: jnp.ndarray,    # [B, n, ...]
    lengths: jnp.ndarray,  # [B] per-request write offsets
) -> jnp.ndarray:
    """Per-request-position write (continuous-batching engine path)."""
    def write_one(c, x, p):
        return jax.lax.dynamic_update_slice(c, x.astype(c.dtype), (p,) + (0,) * (c.ndim - 1))
    return jax.vmap(write_one)(cache, new, lengths)
