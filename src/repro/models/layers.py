"""Shared pure-JAX building blocks (no flax): norms, RoPE, MLPs, init."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict  # nested dicts of jnp arrays


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def split(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., T, H, D]; positions: [..., T] (broadcastable batch dims)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, D/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., T, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = split(key, 3)
    return {
        "wi_gate": dense_init(k1, d_model, d_ff, dtype),
        "wi_up": dense_init(k2, d_model, d_ff, dtype),
        "wo": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(params: Params, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    gate = act_fn(activation)(x @ params["wi_gate"])
    return (gate * (x @ params["wi_up"])) @ params["wo"]
