"""Composable decoder-only / encoder-decoder LM covering all assigned archs.

Design:

* Params are plain nested dicts (no flax). A model is a list of *stacks*; each
  stack is a repeating *period* of (sequence-mixer, channel-mixer) slots whose
  parameters are stacked along a leading ``reps`` axis and driven by
  ``lax.scan`` — compact HLO even for 72-layer models. A dense prefix (e.g.
  DeepSeek-v3's first-3-dense) is simply a second stack.
* Four execution modes share one block implementation:
  ``train`` (no cache), ``prefill`` (build cache, static offset 0, exact tile
  pruning), ``chunk`` (chunked prefill against an existing cache at a traced
  offset — the serving engine's path), ``decode`` (single token).
* Caches are pytrees mirroring the stack structure, leaves ``[reps, B, ...]``
  so they scan together with the params.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ATTN, DENSE, LOCAL_ATTN, MAMBA, MLA, MLSTM, MOE, NONE, SLSTM, ModelConfig,
)
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.layers import dense_init, embed_init, init_mlp, mlp, rms_norm, softcap, split

Params = Any


@dataclasses.dataclass(frozen=True)
class RunCtx:
    """Per-call runtime context (distribution + numerics knobs)."""

    moe: M.MoEContext = dataclasses.field(default_factory=M.MoEContext)
    remat: str = "none"          # none | dots | full
    block_q: int = 512
    block_k: int = 512
    mlstm_block: int = 256
    # beyond-paper perf knobs (hillclimbed; see EXPERIMENTS.md §Perf)
    loss_vocab_blocks: int = 8
    window_cache: bool = False   # rolling-buffer cache for LOCAL_ATTN layers
    # roofline accounting: XLA's cost analysis counts a while-loop body once,
    # so the dry-run's roofline pass lowers with layer scans unrolled.
    unroll_layers: bool = False
    # sharded serving: when set, the paged attention ops run under shard_map
    # on this mesh (KV heads on ``shard_axis`` when they divide it, else the
    # sequence-sharded fallback). None = exact single-device dispatch.
    mesh: Any = None
    shard_axis: str = "model"


# =============================================================================
# stack structure
# =============================================================================
def build_stacks(cfg: ModelConfig) -> list:
    """Returns [(period_kinds, reps)] covering cfg.num_layers decoder layers."""
    stacks = []
    if cfg.first_k_dense:
        mixer0 = cfg.layer_pattern[0]
        stacks.append((((mixer0, DENSE),), cfg.first_k_dense))
    period = tuple(
        (cfg.layer_pattern[i % len(cfg.layer_pattern)],
         cfg.ffn_pattern[i % len(cfg.ffn_pattern)])
        for i in range(cfg.period)
    )
    stacks.append((period, cfg.num_pattern_reps))
    return stacks


def _moe_pad(cfg: ModelConfig) -> int:
    return M.pad_experts(cfg.num_experts, 16)


# =============================================================================
# init
# =============================================================================
def _init_attn_slot(cfg: ModelConfig, key) -> Params:
    d, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = split(key, 4)
    p = {
        "ln": jnp.zeros((d,), cfg.param_dtype),
        "wq": dense_init(ks[0], d, H * Dh, cfg.param_dtype),
        "wk": dense_init(ks[1], d, Hkv * Dh, cfg.param_dtype),
        "wv": dense_init(ks[2], d, Hkv * Dh, cfg.param_dtype),
        "wo": dense_init(ks[3], H * Dh, d, cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((Dh,), cfg.param_dtype)
        p["k_norm"] = jnp.zeros((Dh,), cfg.param_dtype)
    if cfg.post_norm:
        p["post_ln"] = jnp.zeros((d,), cfg.param_dtype)
    return p


def _init_mla_slot(cfg: ModelConfig, key) -> Params:
    d, H = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = split(key, 5)
    return {
        "ln": jnp.zeros((d,), cfg.param_dtype),
        "wq_a": dense_init(ks[0], d, qr, cfg.param_dtype),
        "q_ln": jnp.zeros((qr,), cfg.param_dtype),
        "wq_b": dense_init(ks[1], qr, H * (dn + dr), cfg.param_dtype),
        "wkv_a": dense_init(ks[2], d, kvr + dr, cfg.param_dtype),
        "kv_ln": jnp.zeros((kvr,), cfg.param_dtype),
        "wkv_b": dense_init(ks[3], kvr, H * (dn + dv), cfg.param_dtype),
        "wo": dense_init(ks[4], H * dv, d, cfg.param_dtype),
    }


def _init_cross_slot(cfg: ModelConfig, key) -> Params:
    p = _init_attn_slot(cfg, key)
    p.pop("q_norm", None), p.pop("k_norm", None)
    return p


def _init_slot(cfg: ModelConfig, mixer: str, ffn: str, key, decoder_cross: bool) -> Params:
    d = cfg.d_model
    k_mix, k_ffn, k_cross = split(key, 3)
    slot: Params = {}
    if mixer in (ATTN, LOCAL_ATTN):
        slot["attn"] = _init_attn_slot(cfg, k_mix)
    elif mixer == MLA:
        slot["mla"] = _init_mla_slot(cfg, k_mix)
    elif mixer == MAMBA:
        slot["mamba"] = {
            "ln": jnp.zeros((d,), cfg.param_dtype),
            **S.init_mamba(k_mix, d, cfg.mamba_expand * d, cfg.mamba_d_state,
                           cfg.mamba_d_conv, cfg.param_dtype),
        }
    elif mixer == MLSTM:
        slot["mlstm"] = {"ln": jnp.zeros((d,), cfg.param_dtype),
                         **S.init_mlstm(k_mix, d, cfg.num_heads, cfg.param_dtype)}
    elif mixer == SLSTM:
        slot["slstm"] = {"ln": jnp.zeros((d,), cfg.param_dtype),
                         **S.init_slstm(k_mix, d, cfg.num_heads, cfg.param_dtype)}
    else:
        raise ValueError(mixer)
    if decoder_cross:
        slot["cross"] = _init_cross_slot(cfg, k_cross)
    if ffn == DENSE:
        slot["ffn"] = {"ln": jnp.zeros((d,), cfg.param_dtype),
                       **init_mlp(k_ffn, d, cfg.d_ff, cfg.param_dtype)}
        if cfg.post_norm:
            slot["ffn"]["post_ln"] = jnp.zeros((d,), cfg.param_dtype)
    elif ffn == MOE:
        slot["moe"] = {
            "ln": jnp.zeros((d,), cfg.param_dtype),
            **M.init_moe(k_ffn, d, cfg.moe_d_ff, cfg.num_experts, _moe_pad(cfg),
                         cfg.shared_expert_d_ff, cfg.param_dtype,
                         aux_free=cfg.router_aux_free),
        }
    elif ffn == NONE:
        pass
    else:
        raise ValueError(ffn)
    return slot


def _init_stack(cfg: ModelConfig, period, reps: int, key, decoder_cross: bool) -> Params:
    """Stacked slot params: leaves [reps, ...]."""
    def one_rep(k):
        ks = split(k, len(period))
        return [ _init_slot(cfg, mixer, ffn, ks[i], decoder_cross)
                 for i, (mixer, ffn) in enumerate(period) ]
    reps_keys = split(key, reps)
    per_rep = [one_rep(k) for k in reps_keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep)


def init_params(cfg: ModelConfig, key) -> Params:
    ks = split(key, 6)
    params: Params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, cfg.param_dtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "stacks": [
            _init_stack(cfg, period, reps, k, decoder_cross=cfg.enc_dec)
            for (period, reps), k in zip(build_stacks(cfg), split(ks[1], len(build_stacks(cfg))))
        ],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_size, cfg.param_dtype)
    if cfg.enc_dec:
        n_enc = cfg.num_encoder_layers
        params["encoder"] = {
            "stacks": [_init_stack(cfg, ((ATTN, DENSE),), n_enc, ks[3], decoder_cross=False)],
            "final_norm": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        }
    if cfg.mtp_depth:
        params["mtp"] = {
            "proj": dense_init(ks[4], 2 * cfg.d_model, cfg.d_model, cfg.param_dtype),
            "block": _init_stack(cfg, ((cfg.layer_pattern[0], DENSE),), 1, ks[5], False),
            "ln": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        }
    return params


# =============================================================================
# cache
# =============================================================================
def _slot_cache(cfg: ModelConfig, mixer: str, B: int, Smax: int, dtype,
                decoder_cross: bool, enc_len: int) -> Params:
    Hkv, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    d = cfg.d_model
    c: Params = {}
    if mixer in (ATTN, LOCAL_ATTN):
        c["k"] = jnp.zeros((B, Smax, Hkv, Dh), dtype)
        c["v"] = jnp.zeros((B, Smax, Hkv, Dh), dtype)
    elif mixer == MLA:
        c["ckv"] = jnp.zeros((B, Smax, cfg.kv_lora_rank), dtype)
        c["kr"] = jnp.zeros((B, Smax, cfg.qk_rope_head_dim), dtype)
    elif mixer == MAMBA:
        c["mamba"] = S.init_mamba_state(B, cfg.mamba_expand * d,
                                        cfg.mamba_d_state, cfg.mamba_d_conv, dtype)
    elif mixer == MLSTM:
        c["mlstm"] = S.init_mlstm_state(B, d, cfg.num_heads, dtype)
    elif mixer == SLSTM:
        c["slstm"] = S.init_slstm_state(B, d, cfg.num_heads, dtype)
    if decoder_cross:
        c["cross_k"] = jnp.zeros((B, enc_len, Hkv, Dh), dtype)
        c["cross_v"] = jnp.zeros((B, enc_len, Hkv, Dh), dtype)
    return c


def init_cache(cfg: ModelConfig, B: int, max_len: int, dtype=None, enc_len: int = 0) -> Params:
    dtype = dtype or cfg.dtype
    out = []
    for period, reps in build_stacks(cfg):
        def one_rep():
            return [_slot_cache(cfg, mixer, B, max_len, dtype, cfg.enc_dec, enc_len)
                    for mixer, _ in period]
        per_rep = [one_rep() for _ in range(reps)]
        out.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep))
    return out


# ---- paged cache (block-table KV; serving engine's production layout) -------
class PagedView(NamedTuple):
    """Per-call paged-cache addressing (traced operands, shared by all layers).

    ``block_tables``: [R, n] logical->physical page map per batch row.
    ``write_slots``:  [R*L] flat destination slot (page*page_size + offset)
    for every token in the call, row-major; padding tokens point at the
    reserved trash page.
    """

    block_tables: jnp.ndarray
    write_slots: jnp.ndarray


def supports_paged_cache(cfg: ModelConfig) -> bool:
    """Paged KV covers pure-attention decoders (ATTN/LOCAL_ATTN mixers).
    Recurrent mixers keep O(1) per-request state (nothing to page) and MLA /
    enc-dec have bespoke cache shapes — those archs stay on the slot cache."""
    # (first_k_dense stacks reuse layer_pattern[0] as their mixer — see
    # build_stacks — so checking the pattern set covers them too)
    return not cfg.enc_dec and set(cfg.layer_pattern) <= {ATTN, LOCAL_ATTN}


PAGED_KV_LAYOUT = "fused-head-interleaved-v1"   # cache/upload versioning tag


def _slot_paged_cache(cfg: ModelConfig, mixer: str, num_pages: int,
                      page_size: int, dtype) -> Params:
    Hkv, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    if mixer in (ATTN, LOCAL_ATTN):
        # fused head-interleaved layout (tpu_commons-v3 style): K at
        # interleave index 0, V at 1, adjacent per (head, page) — one pool
        # object, one block-table consumer, one DMA per page.
        return {"kv_pages": jnp.zeros((Hkv, num_pages, 2, page_size, Dh),
                                      dtype)}
    raise ValueError(f"paged cache does not support mixer {mixer!r}")


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     dtype=None) -> Params:
    """Physical fused KV page pools, one [Hkv, num_pages, 2, page_size, Dh]
    leaf per layer (stacked [reps, ...] like ``init_cache``). ``num_pages``
    includes any trash page the caller reserves; there is no batch axis —
    concurrency is bounded by pages, not rows."""
    dtype = dtype or cfg.dtype
    out = []
    for period, reps in build_stacks(cfg):
        per_rep = [[_slot_paged_cache(cfg, mixer, num_pages, page_size, dtype)
                    for mixer, _ in period] for _ in range(reps)]
        out.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep))
    return out


# =============================================================================
# blocks
# =============================================================================
def _norm(x, w, eps):
    return rms_norm(x, w, eps)


def _maybe_post(cfg, p, y):
    return _norm(y, p["post_ln"], cfg.norm_eps) if cfg.post_norm and "post_ln" in p else y


def _qkv(cfg: ModelConfig, p, x, positions):
    B, Sq, _ = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, Sq, H, Dh)
    k = (x @ p["wk"]).reshape(B, Sq, Hkv, Dh)
    v = (x @ p["wv"]).reshape(B, Sq, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        from repro.models.layers import apply_rope
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q.reshape(B, Sq, Hkv, H // Hkv, Dh), k, v


def _attn_scale(cfg: ModelConfig) -> float:
    return cfg.attn_scale or 1.0 / math.sqrt(cfg.resolved_head_dim)


def _positions(mode, pos, lengths, Sq):
    if mode in ("decode", "paged_decode") and lengths is not None and jnp.ndim(lengths):
        return (jnp.asarray(lengths) - 1)[:, None]          # [B, 1] per-request
    pos_arr = jnp.asarray(pos)
    if jnp.ndim(pos_arr):                                   # [B] per-row offsets
        return pos_arr[:, None] + jnp.arange(Sq)[None, :]   # [B, Sq] ragged chunk
    return (pos + jnp.arange(Sq))[None, :]                  # [1, Sq] lockstep


def attn_block(cfg, rctx, p, x, state, *, mode, pos, lengths, window,
               paged=None):
    """Returns (y, new_state)."""
    B, Sq, _ = x.shape
    xin = _norm(x, p["ln"], cfg.norm_eps)
    positions = _positions(mode, pos, lengths, Sq)
    q, k, v = _qkv(cfg, p, xin, positions)
    scale = _attn_scale(cfg)
    new_state = state
    if mode in ("train", "encode"):
        o = A.blockwise_attention(q, k, v, scale=scale, causal=(mode == "train"),
                                  window=window if mode == "train" else 0,
                                  softcap=cfg.attn_logit_softcap,
                                  block_q=rctx.block_q, block_k=rctx.block_k,
                                  unroll=rctx.unroll_layers)
    elif mode == "prefill":
        o = A.blockwise_attention(q, k, v, scale=scale, causal=True, window=window,
                                  softcap=cfg.attn_logit_softcap,
                                  block_q=rctx.block_q, block_k=rctx.block_k,
                                  unroll=rctx.unroll_layers)
        new_state = dict(state,
                         k=A.update_kv_cache(state["k"], k, 0),
                         v=A.update_kv_cache(state["v"], v, 0))
    elif mode == "chunk":
        k_all = A.update_kv_cache(state["k"], k, pos)
        v_all = A.update_kv_cache(state["v"], v, pos)
        new_state = dict(state, k=k_all, v=v_all)
        o = _chunk_attend(cfg, rctx, q, k_all, v_all, pos, lengths, window)
    elif mode == "paged_chunk":
        # fused ragged prefill: scatter the chunk's KV into the fused
        # head-interleaved physical pages with one combined K+V scatter
        # (vLLM slot mapping; padding rows target the trash page), then
        # attend directly over the block tables — no gathered k_all/v_all
        # buffer and no dense [R,H,G,Sq,Sk] score tensor (double-buffered
        # Pallas kernel on TPU, its bit-identical jnp oracle on CPU).
        from repro.kernels.paged_prefill_attention.ops import (
            paged_prefill_attention_auto)
        kvp = A.write_pages_fused(state["kv_pages"], k, v, paged.write_slots)
        new_state = dict(state, kv_pages=kvp)
        o = paged_prefill_attention_auto(
            q, kvp, paged.block_tables, jnp.asarray(pos),
            jnp.asarray(lengths), scale=scale, window=window,
            softcap=cfg.attn_logit_softcap, mesh=rctx.mesh,
            axis=rctx.shard_axis)
    elif mode == "paged_decode":
        from repro.kernels.paged_attention.ops import paged_attention_auto
        kvp = A.write_pages_fused(state["kv_pages"], k, v, paged.write_slots)
        new_state = dict(state, kv_pages=kvp)
        H, Dh = cfg.num_heads, cfg.resolved_head_dim
        o = paged_attention_auto(q[:, 0].reshape(B, H, Dh), kvp,
                                 paged.block_tables, jnp.asarray(lengths),
                                 scale=scale, window=window,
                                 softcap=cfg.attn_logit_softcap,
                                 mesh=rctx.mesh, axis=rctx.shard_axis)
        o = o.reshape(B, q.shape[2], q.shape[3], Dh)[:, None]
    elif mode == "decode":
        if jnp.ndim(lengths):
            k_all = A.update_kv_cache_ragged(state["k"], k, lengths - 1)
            v_all = A.update_kv_cache_ragged(state["v"], v, lengths - 1)
        else:
            k_all = A.update_kv_cache(state["k"], k, pos)
            v_all = A.update_kv_cache(state["v"], v, pos)
        new_state = dict(state, k=k_all, v=v_all)
        o = A.decode_attention(q[:, 0], k_all, v_all, lengths, scale=scale,
                               window=window, softcap=cfg.attn_logit_softcap)[:, None]
    else:
        raise ValueError(mode)
    o = o.reshape(B, Sq, cfg.num_heads * cfg.resolved_head_dim)
    y = o @ p["wo"]
    return _maybe_post(cfg, p, y), new_state


def _chunk_attend(cfg, rctx, q, k_all, v_all, pos, lengths, window, scale=None):
    """Chunk of queries at traced offset ``pos`` over the full cache buffer.

    Causality is enforced by masking against traced positions; no static tile
    pruning (the engine buckets the cache length instead).
    """
    B, Sq = q.shape[0], q.shape[1]
    # q_offset enters only through position masks -> fold into kv_valid mask:
    # row t may see keys < pos + t + 1. Implement via per-row valid length.
    # blockwise_attention supports causal masking with integer q_offset only,
    # so use a non-causal call with explicit row-wise masking in one pass.
    # ``pos`` may be a scalar (lockstep chunk) or a [B] vector (fused ragged
    # chunk batch: each row prefills at its own offset).
    scale = scale if scale is not None else _attn_scale(cfg)
    Hkv, G = q.shape[2], q.shape[3]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k_all, preferred_element_type=jnp.float32) * scale
    s = softcap(s, cfg.attn_logit_softcap) if cfg.attn_logit_softcap else s
    Sk = k_all.shape[1]
    k_pos = jnp.arange(Sk)
    q_pos = jnp.asarray(pos).reshape(-1, 1) + jnp.arange(Sq)[None, :]  # [B|1, Sq]
    mask = k_pos[None, None, :] <= q_pos[:, :, None]         # [B|1, Sq, Sk]
    if window and window > 0:
        mask = mask & (q_pos[:, :, None] - k_pos[None, None, :] < window)
    if lengths is not None and jnp.ndim(lengths):
        mask = mask & (k_pos[None, None, :] < jnp.asarray(lengths).reshape(-1, 1, 1))
    mask = mask[:, None, None]                               # [B|1, 1, 1, Sq, Sk]
    s = jnp.where(mask, s, A.NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_all.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v_all)


def mla_block(cfg, rctx, p, x, state, *, mode, pos, lengths):
    B, Sq, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv, kvr = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                       cfg.v_head_dim, cfg.kv_lora_rank)
    from repro.models.layers import apply_rope
    xin = _norm(x, p["ln"], cfg.norm_eps)
    positions = _positions(mode, pos, lengths, Sq)
    scale = 1.0 / math.sqrt(dn + dr)

    qf = rms_norm(xin @ p["wq_a"], p["q_ln"], cfg.norm_eps) @ p["wq_b"]
    qf = qf.reshape(B, Sq, H, dn + dr)
    q_nope, q_rope = qf[..., :dn], qf[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = xin @ p["wkv_a"]
    ckv = rms_norm(kv[..., :kvr], p["kv_ln"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., kvr:][:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    wkv_b = p["wkv_b"].reshape(kvr, H, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]
    new_state = state

    if mode in ("train", "prefill"):
        k_nope = jnp.einsum("bsr,rhd->bshd", ckv, w_uk)
        v = jnp.einsum("bsr,rhd->bshd", ckv, w_uv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, Sq, H, dr))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)[:, :, :, None, :]  # G=1
        o = A.blockwise_attention(q, k, v, scale=scale, causal=True,
                                  block_q=rctx.block_q, block_k=rctx.block_k,
                                  unroll=rctx.unroll_layers)
        o = o.reshape(B, Sq, H * dv)
        if mode == "prefill":
            new_state = dict(state,
                             ckv=A.update_kv_cache(state["ckv"], ckv, 0),
                             kr=A.update_kv_cache(state["kr"], k_rope, 0))
    elif mode == "decode":
        if jnp.ndim(lengths):
            ckv_all = A.update_kv_cache_ragged(state["ckv"], ckv, lengths - 1)
            kr_all = A.update_kv_cache_ragged(state["kr"], k_rope, lengths - 1)
        else:
            ckv_all = A.update_kv_cache(state["ckv"], ckv, pos)
            kr_all = A.update_kv_cache(state["kr"], k_rope, pos)
        new_state = dict(state, ckv=ckv_all, kr=kr_all)
        q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
        o_lat = A.mla_decode_attention(q_lat, q_rope[:, 0], ckv_all, kr_all,
                                       lengths, scale=scale)
        o = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv).reshape(B, 1, H * dv)
    elif mode == "chunk":
        ckv_all = A.update_kv_cache(state["ckv"], ckv, pos)
        kr_all = A.update_kv_cache(state["kr"], k_rope, pos)
        new_state = dict(state, ckv=ckv_all, kr=kr_all)
        k_nope = jnp.einsum("bsr,rhd->bshd", ckv_all, w_uk)
        v_all = jnp.einsum("bsr,rhd->bshd", ckv_all, w_uv)
        Sk = ckv_all.shape[1]
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all[:, :, None], (B, Sk, H, dr))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)[:, :, :, None, :]
        o = _chunk_attend(cfg, rctx, q, k_full, v_all, pos, lengths, 0, scale=scale)
        o = o.reshape(B, Sq, H * dv)
    else:
        raise ValueError(mode)
    return o @ p["wo"], new_state


def cross_block(cfg, rctx, p, x, enc_out, state, *, mode):
    """Encoder-decoder cross attention; kv cached at prefill."""
    B, Sq, _ = x.shape
    xin = _norm(x, p["ln"], cfg.norm_eps)
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (xin @ p["wq"]).reshape(B, Sq, Hkv, H // Hkv, Dh)
    if mode in ("train", "prefill"):
        k = (enc_out @ p["wk"]).reshape(B, -1, Hkv, Dh)
        v = (enc_out @ p["wv"]).reshape(B, -1, Hkv, Dh)
        new_state = state if mode == "train" else dict(state, cross_k=k.astype(state["cross_k"].dtype),
                                                       cross_v=v.astype(state["cross_v"].dtype))
    else:
        k, v, new_state = state["cross_k"], state["cross_v"], state
    o = A.blockwise_attention(q, k, v, scale=_attn_scale(cfg), causal=False,
                              block_q=rctx.block_q, block_k=rctx.block_k,
                                  unroll=rctx.unroll_layers)
    return o.reshape(B, Sq, H * Dh) @ p["wo"], new_state


def _ffn_apply(cfg, rctx, slot, x):
    """Channel mixer. Returns (y, aux)."""
    if "ffn" in slot:
        y = mlp(slot["ffn"], _norm(x, slot["ffn"]["ln"], cfg.norm_eps), cfg.activation)
        return _maybe_post(cfg, slot["ffn"], y), jnp.zeros((), jnp.float32)
    if "moe" in slot:
        y, aux = M.moe_ffn(slot["moe"], _norm(x, slot["moe"]["ln"], cfg.norm_eps),
                           num_real=cfg.num_experts, top_k=cfg.num_experts_per_tok,
                           activation=cfg.activation, aux_free=cfg.router_aux_free,
                           ctx=rctx.moe)
        return y, aux
    return None, jnp.zeros((), jnp.float32)


def apply_slot(cfg, rctx, slot, kinds, x, state, enc_out, *, mode, pos, lengths,
               paged=None):
    mixer, ffn = kinds
    if mixer in (ATTN, LOCAL_ATTN):
        window = cfg.sliding_window if mixer == LOCAL_ATTN else 0
        y, new_state = attn_block(cfg, rctx, slot["attn"], x, state,
                                  mode=mode, pos=pos, lengths=lengths,
                                  window=window, paged=paged)
    elif mixer == MLA:
        y, new_state = mla_block(cfg, rctx, slot["mla"], x, state,
                                 mode=mode, pos=pos, lengths=lengths)
    elif mixer == MAMBA:
        p = slot["mamba"]
        st = None if mode == "train" else state["mamba"]
        y, new_mamba = S.mamba_mix({k: v for k, v in p.items() if k != "ln"},
                                   _norm(x, p["ln"], cfg.norm_eps), st)
        new_state = state if mode == "train" else dict(state, mamba=new_mamba)
    elif mixer == MLSTM:
        p = slot["mlstm"]
        st = None if mode == "train" else state["mlstm"]
        y, new_m = S.mlstm_mix({k: v for k, v in p.items() if k != "ln"},
                               _norm(x, p["ln"], cfg.norm_eps), st, cfg.num_heads,
                               block=min(rctx.mlstm_block, x.shape[1]))
        new_state = state if mode == "train" else dict(state, mlstm=new_m)
    elif mixer == SLSTM:
        p = slot["slstm"]
        st = None if mode == "train" else state["slstm"]
        y, new_s = S.slstm_mix({k: v for k, v in p.items() if k != "ln"},
                               _norm(x, p["ln"], cfg.norm_eps), st, cfg.num_heads)
        new_state = state if mode == "train" else dict(state, slstm=new_s)
    else:
        raise ValueError(mixer)
    x = x + y
    if "cross" in slot and (enc_out is not None or mode in ("decode", "chunk")):
        yc, new_state2 = cross_block(cfg, rctx, slot["cross"], x, enc_out,
                                     new_state, mode=mode)
        x = x + yc
        if mode != "train":
            new_state = new_state2
    y_ffn, aux = _ffn_apply(cfg, rctx, slot, x)
    if y_ffn is not None:
        x = x + y_ffn
    return x, new_state, aux


def _remat_wrap(rctx, fn):
    if rctx.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if rctx.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def apply_stack(cfg, rctx, stack_params, period, x, cache, enc_out, *,
                mode, pos, lengths, paged=None):
    """Scan the stack. cache may be None (train). Returns (x, new_cache, aux)."""
    has_cache = cache is not None

    def body(carry, per_rep):
        x, aux = carry
        if has_cache:
            p_rep, c_rep = per_rep
        else:
            p_rep, c_rep = per_rep, [None] * len(period)
        new_c = []
        for i, kinds in enumerate(period):
            x, st, a = apply_slot(cfg, rctx, p_rep[i], kinds, x, c_rep[i],
                                  enc_out, mode=mode, pos=pos, lengths=lengths,
                                  paged=paged)
            new_c.append(st)
            aux = aux + a
        return (x, aux), (new_c if has_cache else None)

    body = _remat_wrap(rctx, body)
    xs = (stack_params, cache) if has_cache else stack_params
    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs,
        unroll=True if rctx.unroll_layers else 1)
    return x, new_cache, aux


# =============================================================================
# top level
# =============================================================================
def _embed(cfg, params, tokens, extra_embeds=None):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if extra_embeds is not None and cfg.num_patch_tokens:
        P = extra_embeds.shape[1]
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x[:, P:]], axis=1)
    return x


def _head(cfg, params, x):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ w
    return softcap(logits, cfg.final_logit_softcap)


def _run_encoder(cfg, rctx, params, enc_embeds):
    enc = params["encoder"]
    x = enc_embeds
    x, _, _ = apply_stack(cfg, rctx, enc["stacks"][0], ((ATTN, DENSE),), x, None,
                          None, mode="encode", pos=0, lengths=None)
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def forward(cfg: ModelConfig, params: Params, tokens, *, rctx: RunCtx,
            cache=None, mode: str = "train", pos=0, lengths=None,
            extra_embeds=None, enc_embeds=None, paged=None):
    """Unified forward. Returns (hidden [B,S,d], new_cache, aux, enc_out)."""
    enc_out = None
    if cfg.enc_dec:
        if enc_embeds is not None:
            enc_out = _run_encoder(cfg, rctx, params, enc_embeds)
    x = _embed(cfg, params, tokens, extra_embeds)
    new_stacks = []
    aux_total = jnp.zeros((), jnp.float32)
    stacks = build_stacks(cfg)
    for i, (period, reps) in enumerate(stacks):
        c = cache[i] if cache is not None else None
        x, new_c, aux = apply_stack(cfg, rctx, params["stacks"][i], period, x, c,
                                    enc_out, mode=mode, pos=pos, lengths=lengths,
                                    paged=paged)
        new_stacks.append(new_c)
        aux_total = aux_total + aux
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, (new_stacks if cache is not None else None), aux_total, enc_out


# ---- user-facing steps ------------------------------------------------------
def loss_fn(cfg: ModelConfig, params: Params, batch: dict, rctx: RunCtx):
    """Next-token CE loss (+ MoE aux + optional MTP). batch: tokens [B,S] (+
    extra_embeds / enc_embeds)."""
    tokens = batch["tokens"]
    x, _, aux, _ = forward(cfg, params, tokens, rctx=rctx, mode="train",
                           extra_embeds=batch.get("extra_embeds"),
                           enc_embeds=batch.get("enc_embeds"))
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    loss = _blocked_ce(cfg, params, x, labels, mask, rctx)
    if cfg.mtp_depth and "mtp" in params:
        loss = loss + 0.3 * _mtp_loss(cfg, params, x, tokens, rctx)
    return loss + 0.01 * aux


def _blocked_ce(cfg, params, x, labels, mask, rctx):
    """Cross-entropy without materializing [B,S,V] in fp32 all at once."""
    B, S, _ = x.shape
    nb = min(rctx.loss_vocab_blocks, S)
    while S % nb:
        nb -= 1
    xs = x.reshape(B, nb, S // nb, -1)
    ls = labels.reshape(B, nb, S // nb)
    ms = mask.reshape(B, nb, S // nb)
    head_w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

    @jax.checkpoint  # recompute block logits in bwd: never hold [B,S,V] fp32
    def block_ce(xb, lb, mb, w):
        logits = softcap(xb @ w, cfg.final_logit_softcap).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mb)

    total = jnp.zeros((), jnp.float32)
    for i in range(nb):
        total = total + block_ce(xs[:, i], ls[:, i], ms[:, i], head_w)
    return total / jnp.maximum(jnp.sum(mask), 1.0)


def _mtp_loss(cfg, params, hidden, tokens, rctx):
    """DeepSeek-v3 multi-token prediction: one extra depth predicting t+2."""
    mtp = params["mtp"]
    emb_next = _embed(cfg, params, jnp.roll(tokens, -1, axis=1))
    h = jnp.concatenate([rms_norm(hidden, mtp["ln"], cfg.norm_eps), emb_next], -1) @ mtp["proj"]
    period = ((cfg.layer_pattern[0], DENSE),)
    h, _, _ = apply_stack(cfg, rctx, mtp["block"], period, h, None, None,
                          mode="train", pos=0, lengths=None)
    labels2 = jnp.roll(tokens, -2, axis=1)
    mask = jnp.ones_like(labels2, jnp.float32).at[:, -2:].set(0.0)
    return _blocked_ce(cfg, params, h, labels2, mask, rctx)


def prefill(cfg: ModelConfig, params: Params, tokens, cache, *, rctx: RunCtx,
            extra_embeds=None, enc_embeds=None):
    """Full prefill from empty cache. Returns (last_logits [B,V], cache)."""
    x, new_cache, _, _ = forward(cfg, params, tokens, rctx=rctx, cache=cache,
                                 mode="prefill", pos=0,
                                 lengths=None, extra_embeds=extra_embeds,
                                 enc_embeds=enc_embeds)
    return _head(cfg, params, x[:, -1]), new_cache


def decode_step(cfg: ModelConfig, params: Params, tokens, cache, pos, *,
                rctx: RunCtx, lengths=None):
    """One decode step. tokens [B,1]; pos scalar (lockstep) or lengths [B]."""
    if lengths is None:
        lengths = pos + 1
    x, new_cache, _, _ = forward(cfg, params, tokens, rctx=rctx, cache=cache,
                                 mode="decode", pos=pos, lengths=lengths)
    return _head(cfg, params, x[:, -1]), new_cache


def chunk_prefill_step(cfg: ModelConfig, params: Params, tokens, cache, pos, *,
                       rctx: RunCtx, lengths=None, extra_embeds=None,
                       logits_at=-1):
    """Chunked-prefill step at traced offset ``pos`` (serving engine path).

    ``logits_at``: chunk position whose logits to return (bucket-padded
    chunks must point at the last *real* token, not the padding)."""
    x, new_cache, _, _ = forward(cfg, params, tokens, rctx=rctx, cache=cache,
                                 mode="chunk", pos=pos, lengths=lengths,
                                 extra_embeds=extra_embeds)
    if isinstance(logits_at, int) and logits_at == -1:
        sel = x[:, -1]
    else:
        sel = jnp.take_along_axis(
            x, jnp.asarray(logits_at).reshape(-1, 1, 1), axis=1)[:, 0]
    return _head(cfg, params, sel), new_cache


def _greedy_sample(cfg: ModelConfig, params: Params, hidden) -> jnp.ndarray:
    """On-device greedy sampling: argmax fused over the LM head so the paged
    steps hand back [R] int32 token ids instead of [R, V] logits — the engine
    never pulls a logits tensor (or a per-row scalar) across the host-device
    boundary."""
    return jnp.argmax(_head(cfg, params, hidden), axis=-1).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class Sampling:
    """On-device sampling spec for the paged steps.

    ``temperature <= 0`` is exact greedy argmax — the zero-sync engine's
    bit-identity bar — and is compiled out: the sampling branch only exists
    in the jitted program when a positive temperature was configured at
    engine build time. ``seed`` anchors the stream; the engine threads a
    monotonically increasing per-dispatch ``nonce`` so every round (and
    every split dispatch within a round) draws from a distinct fold of the
    key while staying reproducible across serve/step, overlap on/off, and
    meshes (every operand replicates, and the threefry key derivation is
    device-count independent)."""

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def _sample_tokens(cfg: ModelConfig, params: Params, hidden,
                   sampling: Optional[Sampling], nonce) -> jnp.ndarray:
    """Fused LM-head + token selection on device: ``hidden`` [..., d] ->
    int32 token ids [...]. Greedy (``sampling`` None or temperature <= 0)
    lowers to exactly :func:`_greedy_sample`; otherwise temperature/top-k
    categorical sampling with the RNG key folded from the traced ``nonce``
    (independent Gumbel noise per row/position — multi-row and multi-position
    batches sample each logit row independently)."""
    if sampling is None or sampling.greedy or nonce is None:
        return _greedy_sample(cfg, params, hidden)
    logits = _head(cfg, params, hidden).astype(jnp.float32)
    logits = logits / jnp.float32(sampling.temperature)
    if 0 < sampling.top_k < logits.shape[-1]:
        kth = jax.lax.top_k(logits, sampling.top_k)[0][..., -1:]
        logits = jnp.where(logits >= kth, logits,
                           jnp.finfo(logits.dtype).min)
    key = jax.random.fold_in(jax.random.PRNGKey(sampling.seed),
                             jnp.asarray(nonce, jnp.uint32))
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def paged_chunk_step(cfg: ModelConfig, params: Params, tokens, cache, row_pos, *,
                     rctx: RunCtx, row_lens, block_tables, write_slots,
                     logits_at, sampling: Optional[Sampling] = None,
                     nonce=None):
    """Fused ragged chunked-prefill step over the paged cache.

    One dispatch advances *every* prefill row in the decision: ``tokens``
    [R, L] holds each request's chunk (bucket-padded), ``row_pos`` [R] its
    cache offset, ``row_lens`` [R] its post-chunk valid length, ``logits_at``
    [R] the index of its last real token. Returns (token_ids [R] int32,
    cache) — sampling happens on device (see ``_sample_tokens``)."""
    x, new_cache, _, _ = forward(cfg, params, tokens, rctx=rctx, cache=cache,
                                 mode="paged_chunk", pos=row_pos, lengths=row_lens,
                                 paged=PagedView(block_tables, write_slots))
    sel = jnp.take_along_axis(
        x, jnp.asarray(logits_at).reshape(-1, 1, 1), axis=1)[:, 0]
    return _sample_tokens(cfg, params, sel, sampling, nonce), new_cache


def paged_decode_step(cfg: ModelConfig, params: Params, tokens, cache, *,
                      rctx: RunCtx, lengths, block_tables, write_slots,
                      sampling: Optional[Sampling] = None, nonce=None):
    """One decode step for a ragged row batch over the paged cache (the
    paged_attention kernel on TPU, its jnp oracle elsewhere). ``lengths`` [R]
    counts each row's tokens *including* the one being written. Returns
    (token_ids [R] int32, cache) — sampling happens on device."""
    x, new_cache, _, _ = forward(cfg, params, tokens, rctx=rctx, cache=cache,
                                 mode="paged_decode", pos=0, lengths=lengths,
                                 paged=PagedView(block_tables, write_slots))
    return _sample_tokens(cfg, params, x[:, -1], sampling, nonce), new_cache


def paged_spec_step(cfg: ModelConfig, params: Params, tokens, cache, row_pos, *,
                    rctx: RunCtx, row_lens, block_tables, write_slots,
                    sampling: Optional[Sampling] = None, nonce=None):
    """Speculative **verify** step: multi-token decode rows with on-device
    accept/reject, executed through the same fused ragged paged-prefill path
    as ``paged_chunk_step`` (Sq > 1 rows at arbitrary offsets).

    ``tokens`` [R, S] holds each row's pending token followed by its draft
    candidates (bucket-padded past ``n_i = row_lens_i - row_pos_i``);
    ``row_pos`` [R] is the row's resident cache length (the first write
    position), ``row_lens`` [R] = ``row_pos + n_i``. The model's output
    ``out[:, j]`` is its next-token choice given the context through input
    position ``j``; draft ``tokens[:, j+1]`` is accepted iff every earlier
    draft matched, so the emitted stream ``out[:, :a+1]`` (``a`` accepted
    drafts + one bonus token) is *exactly* the autoregressive sample/argmax
    sequence — greedy tokens are bit-identical to plain decode at any k.

    Returns ``(payload int32 [R * (S+1)], cache)``: per row
    ``[accepted, out_0 .. out_{S-1}]`` raveled, so the engine's single
    deferred readback per round carries accepted lengths and token ids
    together and rolls back rejected tail positions host-side (their KV
    writes landed in already-owned pages and are simply overwritten)."""
    S = tokens.shape[1]
    x, new_cache, _, _ = forward(cfg, params, tokens, rctx=rctx, cache=cache,
                                 mode="paged_chunk", pos=row_pos, lengths=row_lens,
                                 paged=PagedView(block_tables, write_slots))
    out = _sample_tokens(cfg, params, x, sampling, nonce)        # [R, S]
    n_real = (jnp.asarray(row_lens) - jnp.asarray(row_pos))[:, None]
    jidx = jnp.arange(1, S)[None, :]
    matches = (tokens[:, 1:] == out[:, :-1]) & (jidx < n_real)
    accepted = jnp.cumprod(matches.astype(jnp.int32), axis=1).sum(axis=1)
    payload = jnp.concatenate([accepted[:, None], out], axis=1)
    return payload.reshape(-1).astype(jnp.int32), new_cache


def build_model(cfg: ModelConfig, rctx: Optional[RunCtx] = None):
    """Convenience bundle of partially-applied step functions."""
    rctx = rctx or RunCtx()
    return {
        "init_params": partial(init_params, cfg),
        "init_cache": partial(init_cache, cfg),
        "loss_fn": partial(loss_fn, cfg, rctx=rctx),
        "prefill": partial(prefill, cfg, rctx=rctx),
        "decode_step": partial(decode_step, cfg, rctx=rctx),
        "chunk_prefill_step": partial(chunk_prefill_step, cfg, rctx=rctx),
    }
