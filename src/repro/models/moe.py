"""Mixture-of-Experts channel mixer.

Two implementations selected by ``MoEContext.impl``:

* ``dense`` — every expert on every token, masked combine. Exact, used by CPU
  smoke tests and tiny configs only (FLOPs scale with total experts).
* ``ep`` — expert parallelism via ``shard_map``: tokens are sequence-sharded
  over the model axis, dispatched into per-expert capacity buffers with the
  Switch-style cumsum trick (one-hot is only [T_local, E]), exchanged with
  ``all_to_all`` over the model axis, run through the local expert shards, and
  combined. Compiled FLOPs ≈ active-expert FLOPs × capacity factor — this is
  what makes the MoE roofline honest (a masked-dense MoE would inflate the
  compute term by E/top_k).

Routing follows the arch: softmax top-k (Jamba/Qwen) or DeepSeek-v3
aux-loss-free sigmoid routing with a correction bias that is updated outside
the gradient path (``update_router_bias``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.shard_utils import shard_map

from repro.models.layers import act_fn, dense_init, split


@dataclasses.dataclass(frozen=True)
class MoEContext:
    """Runtime distribution context for the MoE block."""

    impl: str = "dense"                      # "dense" | "ep"
    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ()            # e.g. ("pod", "data") or ("data",)
    tp_axis: str = "model"
    capacity_factor: float = 1.25


def pad_experts(num_experts: int, multiple: int = 16) -> int:
    return (num_experts + multiple - 1) // multiple * multiple


def init_moe(key, d_model: int, moe_d_ff: int, num_experts: int,
             num_experts_padded: int, shared_d_ff: int, dtype,
             aux_free: bool = False):
    ks = split(key, 5)
    E = num_experts_padded
    scale = 1.0 / math.sqrt(d_model)
    params = {
        "router": dense_init(ks[0], d_model, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d_model, moe_d_ff), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d_model, moe_d_ff), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, moe_d_ff, d_model), jnp.float32)
                   / math.sqrt(moe_d_ff)).astype(dtype),
    }
    if aux_free:
        params["router_bias"] = jnp.zeros((E,), jnp.float32)
    if shared_d_ff:
        k1, k2, k3 = split(ks[4], 3)
        params["shared"] = {
            "wi_gate": dense_init(k1, d_model, shared_d_ff, dtype),
            "wi_up": dense_init(k2, d_model, shared_d_ff, dtype),
            "wo": dense_init(k3, shared_d_ff, d_model, dtype),
        }
    return params


def _route(params, t: jnp.ndarray, num_real: int, top_k: int, aux_free: bool):
    """t: [T, d]. Returns (ids [T,K], weights [T,K] fp32, aux_loss scalar)."""
    E = params["router"].shape[1]
    logits = (t.astype(jnp.float32) @ params["router"])  # [T, E]
    if E > num_real:
        pad_mask = jnp.arange(E) >= num_real
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    if aux_free:
        scores = jax.nn.sigmoid(logits)
        sel = scores + params["router_bias"][None, :]
        sel = jnp.where(jnp.arange(E)[None, :] >= num_real, -1e30, sel) if E > num_real else sel
        _, ids = jax.lax.top_k(sel, top_k)
        w = jnp.take_along_axis(scores, ids, axis=-1)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
        aux = jnp.zeros((), jnp.float32)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, ids = jax.lax.top_k(probs, top_k)
        w = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
        # Switch-style load balance loss on the real experts.
        me = jnp.mean(probs[:, :num_real], axis=0)
        onehot = jax.nn.one_hot(ids[:, 0], E)[:, :num_real]
        ce = jnp.mean(onehot, axis=0)
        aux = num_real * jnp.sum(me * ce)
    return ids, w, aux


def _expert_ffn(x, w_gate, w_up, w_down, activation: str):
    """x: [E, C, d]; weights [E, d, f]/[E, f, d]."""
    g = act_fn(activation)(jnp.einsum("ecd,edf->ecf", x, w_gate))
    h = g * jnp.einsum("ecd,edf->ecf", x, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_ffn_dense(params, x: jnp.ndarray, num_real: int, top_k: int,
                  activation: str, aux_free: bool):
    """Masked-dense MoE: all experts on all tokens. [B,S,d] -> ([B,S,d], aux)."""
    B, S, d = x.shape
    E = params["w_gate"].shape[0]
    t = x.reshape(-1, d)
    ids, w, aux = _route(params, t, num_real, top_k, aux_free)
    gates = jnp.zeros((t.shape[0], E), jnp.float32)
    gates = gates.at[jnp.arange(t.shape[0])[:, None], ids].set(w)
    h = _expert_ffn(
        jnp.broadcast_to(t[None], (E,) + t.shape).astype(x.dtype),
        params["w_gate"], params["w_up"], params["w_down"], activation,
    )  # [E, T, d]
    y = jnp.einsum("etd,te->td", h.astype(jnp.float32), gates)
    return y.reshape(B, S, d).astype(x.dtype), aux


def _dispatch_local(t, ids, w, E: int, cap: int):
    """Token->expert capacity dispatch on one shard.

    t: [T, d]; ids/w: [T, K]. Returns (buf [E, cap, d], meta for combine).
    """
    T, K = ids.shape
    flat_ids = ids.reshape(-1)                        # [T*K]
    oh = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)  # [T*K, E]
    pos_all = jnp.cumsum(oh, axis=0) - 1              # position within expert
    pos = jnp.take_along_axis(pos_all, flat_ids[:, None], axis=1)[:, 0]
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, cap)              # cap -> dropped by 'drop'
    t_rep = jnp.repeat(t, K, axis=0)                  # [T*K, d]
    buf = jnp.zeros((E, cap + 1, t.shape[1]), t.dtype)
    buf = buf.at[flat_ids, safe_pos].set(t_rep, mode="drop")[:, :cap]
    return buf, (flat_ids, safe_pos, keep)


def _combine_local(buf_out, meta, w, T: int, K: int):
    flat_ids, safe_pos, keep = meta
    gathered = buf_out[flat_ids, jnp.minimum(safe_pos, buf_out.shape[1] - 1)]  # [T*K, d]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    wf = w.reshape(-1)[:, None].astype(gathered.dtype)
    y = (gathered * wf).reshape(T, K, -1).sum(axis=1)
    return y


def moe_ffn_ep(params, x: jnp.ndarray, num_real: int, top_k: int,
               activation: str, aux_free: bool, ctx: MoEContext):
    """Expert-parallel MoE via shard_map. x: [B, S, d] sharded (dp, tp, -)."""
    mesh = ctx.mesh
    E = params["w_gate"].shape[0]
    tp = ctx.tp_axis
    M = mesh.shape[tp]
    assert E % M == 0, f"experts {E} not divisible by model axis {M}"
    dp = ctx.dp_axes

    if x.shape[1] % M != 0:
        # decode path: sequences too short to sequence-shard over the model
        # axis -> replicated-dispatch EP (tokens replicated across the model
        # axis, each rank runs its expert shard densely, psum combines).
        # Token counts are tiny at decode so duplicated routing is free and
        # no all_to_all is needed.
        return _moe_ep_replicated(params, x, num_real, top_k, activation,
                                  aux_free, ctx)

    x_spec = P(dp if len(dp) > 1 else (dp[0] if dp else None), tp, None)
    router_spec = P(None, None)
    ew_spec = P(tp, None, None)
    bias_spec = P(None)

    def ep_body(x_loc, router_w, router_bias, w_gate, w_up, w_down):
        Bl, Sl, d = x_loc.shape
        t = x_loc.reshape(-1, d)
        T = t.shape[0]
        local_params = {"router": router_w}
        if router_bias is not None:
            local_params["router_bias"] = router_bias
        ids, w, aux = _route(local_params, t, num_real, top_k, aux_free)
        cap = max(8, int(math.ceil(T * top_k * ctx.capacity_factor / E / 8)) * 8)
        buf, meta = _dispatch_local(t, ids, w, E, cap)           # [E, cap, d]
        El = E // M
        # exchange: [E, cap, d] -> per-device experts gathered from all peers
        buf4 = buf.reshape(M, El, cap, d)
        recv = jax.lax.all_to_all(buf4, tp, split_axis=0, concat_axis=0, tiled=False)
        xin = recv.transpose(1, 0, 2, 3).reshape(El, M * cap, d)  # [El, M*cap, d]
        h = _expert_ffn(xin, w_gate, w_up, w_down, activation)
        back = h.reshape(El, M, cap, d).transpose(1, 0, 2, 3)     # [M, El, cap, d]
        buf_out = jax.lax.all_to_all(back, tp, split_axis=0, concat_axis=0, tiled=False)
        buf_out = buf_out.reshape(E, cap, d)
        y = _combine_local(buf_out, meta, w, T, top_k)
        axes = tuple(dp) + (tp,)
        aux = jax.lax.pmean(aux, axes)
        return y.reshape(Bl, Sl, d), aux

    rb = params.get("router_bias")
    fn = shard_map(
        ep_body, mesh=mesh,
        in_specs=(x_spec, router_spec, bias_spec if rb is not None else P(), ew_spec, ew_spec, ew_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    y, aux = fn(x, params["router"], rb if rb is not None else jnp.zeros((), jnp.float32),
                params["w_gate"], params["w_up"], params["w_down"])
    return y.astype(x.dtype), aux


def _moe_ep_replicated(params, x: jnp.ndarray, num_real: int, top_k: int,
                       activation: str, aux_free: bool, ctx: MoEContext):
    mesh = ctx.mesh
    tp = ctx.tp_axis
    M = mesh.shape[tp]
    E = params["w_gate"].shape[0]
    El = E // M
    dp = ctx.dp_axes
    dp_n = 1
    for a in dp:
        dp_n *= mesh.shape[a]
    bdim = (dp if len(dp) > 1 else (dp[0] if dp else None)) \
        if x.shape[0] % max(dp_n, 1) == 0 else None
    x_spec = P(bdim, None, None)   # batch-1 (long-context) fully replicates
    ew_spec = P(tp, None, None)

    def body(x_loc, router_w, router_bias, w_gate, w_up, w_down):
        Bl, Sl, d = x_loc.shape
        t = x_loc.reshape(-1, d)
        local_params = {"router": router_w}
        if router_bias is not None and router_bias.ndim:
            local_params["router_bias"] = router_bias
        ids, w, aux = _route(local_params, t, num_real, top_k, aux_free)
        rank = jax.lax.axis_index(tp)
        lo = rank * El
        # gate weights for MY local experts only; everything else contributes 0
        local_gate = jnp.zeros((t.shape[0], El), jnp.float32)
        for kk in range(top_k):
            eid = ids[:, kk]
            mine = (eid >= lo) & (eid < lo + El)
            idx = jnp.clip(eid - lo, 0, El - 1)
            local_gate = local_gate.at[jnp.arange(t.shape[0]), idx].add(
                jnp.where(mine, w[:, kk], 0.0))
        h = _expert_ffn(jnp.broadcast_to(t[None], (El,) + t.shape).astype(x.dtype),
                        w_gate, w_up, w_down, activation)     # [El, T, d]
        y = jnp.einsum("etd,te->td", h.astype(jnp.float32), local_gate)
        y = jax.lax.psum(y, tp)
        aux = jax.lax.pmean(aux, tuple(dp) + (tp,)) if dp else jax.lax.pmean(aux, tp)
        return y.reshape(Bl, Sl, d).astype(x.dtype), aux

    rb = params.get("router_bias")
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), P(None) if rb is not None else P(),
                  ew_spec, ew_spec, ew_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    y, aux = fn(x, params["router"], rb if rb is not None else jnp.zeros((), jnp.float32),
                params["w_gate"], params["w_up"], params["w_down"])
    return y, aux


def moe_ffn(params, x: jnp.ndarray, *, num_real: int, top_k: int,
            activation: str, aux_free: bool, ctx: MoEContext):
    """Full MoE block: routed experts + optional shared expert."""
    if ctx.impl == "ep":
        y, aux = moe_ffn_ep(params, x, num_real, top_k, activation, aux_free, ctx)
    else:
        y, aux = moe_ffn_dense(params, x, num_real, top_k, activation, aux_free)
    if "shared" in params:
        sp = params["shared"]
        g = act_fn(activation)(x @ sp["wi_gate"])
        y = y + (g * (x @ sp["wi_up"])) @ sp["wo"]
    return y, aux


def update_router_bias(params, expert_load: jnp.ndarray, num_real: int,
                       step_size: float = 1e-3):
    """DeepSeek-v3 aux-loss-free balancing: nudge bias against load imbalance.

    expert_load: [E] fraction of tokens routed to each expert this step.
    """
    target = 1.0 / num_real
    err = jnp.where(jnp.arange(expert_load.shape[0]) < num_real,
                    target - expert_load, 0.0)
    return dict(params, router_bias=params["router_bias"] + step_size * jnp.sign(err))
