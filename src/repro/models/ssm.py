"""Recurrent sequence mixers: Mamba-1 selective SSM, xLSTM mLSTM / sLSTM.

Cost-accounting notes (see DESIGN.md §Roofline-methodology):

* Mamba's selective scan is a sequential ``lax.scan`` over time. Its FLOPs are
  O(S·d_inner·d_state) — ~0.2% of the surrounding projections — so the XLA
  while-loop body-counted-once artifact is negligible for the compute term;
  the analytic model in ``repro.analysis.flops`` adds the exact term anyway.
* mLSTM uses the *stabilized quadratic form* over statically-enumerated tile
  pairs (same machinery as ``attention.blockwise_attention``), so every FLOP
  appears in the HLO. The chunkwise-recurrent Pallas kernel is the TPU perf
  path (``repro.kernels.mlstm_chunkwise``).
* sLSTM is inherently sequential (recurrent gate feedback) — ``lax.scan``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, split

NEG_INF = -1e30


# =============================================================================
# Mamba-1 selective SSM
# =============================================================================
def init_mamba(key, d_model: int, d_inner: int, d_state: int, d_conv: int, dtype):
    dt_rank = max(1, math.ceil(d_model / 16))
    ks = split(key, 6)
    # S4D-real initialization for A.
    A = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, 1))
    dt = jnp.exp(
        jax.random.uniform(ks[4], (d_inner,), jnp.float32)
        * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    inv_softplus_dt = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * d_state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner, dtype),
        "dt_bias": inv_softplus_dt.astype(jnp.float32),
        "A_log": jnp.log(A),          # fp32 [d_inner, d_state]
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[5], d_inner, d_model, dtype),
    }


def _mamba_conv_full(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                     init_state: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Causal depthwise conv over [B, S, d_inner]; returns (y, new_conv_state).

    ``init_state`` is the last (d_conv-1) inputs of the previous chunk
    ([B, d_conv-1, d_inner]) or None for sequence start.
    """
    B, S, d = x.shape
    K = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((B, K - 1, d), x.dtype)
    xp = jnp.concatenate([init_state.astype(x.dtype), x], axis=1)  # [B, S+K-1, d]
    y = sum(xp[:, i : i + S] * w[i][None, None, :] for i in range(K))
    new_state = jax.lax.dynamic_slice_in_dim(xp, S, K - 1, axis=1)
    return jax.nn.silu(y + b[None, None, :]), new_state


def mamba_mix(params, x: jnp.ndarray, state: Optional[dict]) -> Tuple[jnp.ndarray, dict]:
    """Full Mamba block mix over a chunk [B, S, d_model].

    ``state``: {"conv": [B, K-1, d_inner], "ssm": [B, d_inner, d_state] fp32}
    or None at sequence start. Returns (out [B, S, d_model], new_state).
    """
    B, S, _ = x.shape
    d_inner = params["in_proj"].shape[1] // 2
    d_state = params["A_log"].shape[1]
    dt_rank = params["dt_proj"].shape[0]

    xz = x @ params["in_proj"]
    xm, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    xm, new_conv = _mamba_conv_full(xm, params["conv_w"], params["conv_b"], conv_state)

    dbc = xm @ params["x_proj"]
    dt_raw = dbc[..., :dt_rank]
    Bmat = dbc[..., dt_rank : dt_rank + d_state].astype(jnp.float32)   # [B,S,n]
    Cmat = dbc[..., dt_rank + d_state :].astype(jnp.float32)           # [B,S,n]
    dt = jax.nn.softplus(
        (dt_raw @ params["dt_proj"]).astype(jnp.float32) + params["dt_bias"]
    )  # [B,S,d_inner]
    A = -jnp.exp(params["A_log"])  # [d_inner, n]
    xf = xm.astype(jnp.float32)

    h0 = (
        jnp.zeros((B, d_inner, d_state), jnp.float32)
        if state is None
        else state["ssm"].astype(jnp.float32)
    )

    def step(h, inp):
        dt_t, B_t, C_t, x_t = inp  # [B,d],[B,n],[B,n],[B,d]
        da = jnp.exp(dt_t[..., None] * A[None])              # [B,d,n]
        h = da * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (
        dt.transpose(1, 0, 2),
        Bmat.transpose(1, 0, 2),
        Cmat.transpose(1, 0, 2),
        xf.transpose(1, 0, 2),
    )
    hT, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + params["D"][None, None, :] * xf
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return out, {"conv": new_conv, "ssm": hT}


def init_mamba_state(B: int, d_inner: int, d_state: int, d_conv: int, dtype) -> dict:
    return {
        "conv": jnp.zeros((B, d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((B, d_inner, d_state), jnp.float32),
    }


# =============================================================================
# xLSTM mLSTM (matrix memory)
# =============================================================================
def init_mlstm(key, d_model: int, num_heads: int, dtype):
    """mLSTM block params. Inner dim = 2*d_model (paper's up-projection)."""
    di = 2 * d_model
    ks = split(key, 7)
    return {
        "up_proj": dense_init(ks[0], d_model, 2 * di, dtype),      # -> (xm, z)
        "conv_w": (jax.random.normal(ks[1], (4, di), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": dense_init(ks[2], di, di, dtype),
        "wk": dense_init(ks[3], di, di, dtype),
        "wv": dense_init(ks[4], di, di, dtype),
        "w_gates": dense_init(ks[5], di, 2 * num_heads, dtype),    # (i, f) per head
        "gate_bias": jnp.concatenate(
            [jnp.zeros((num_heads,)), jnp.linspace(3.0, 6.0, num_heads)]
        ).astype(jnp.float32),
        "gn_scale": jnp.zeros((di,), dtype),
        "down_proj": dense_init(ks[6], di, d_model, dtype),
    }


def _group_norm_heads(x: jnp.ndarray, scale: jnp.ndarray, H: int, eps: float = 1e-6):
    """Per-head group norm of [B, S, di] (di = H*Dh)."""
    B, S, di = x.shape
    xh = x.reshape(B, S, H, di // H).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    out = xh.reshape(B, S, di) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def mlstm_mix(params, x: jnp.ndarray, state: Optional[dict], num_heads: int,
              block: int = 512) -> Tuple[jnp.ndarray, dict]:
    """mLSTM over a chunk [B, S, d_model] with optional carried state.

    Stabilized quadratic form over static tile pairs (exact HLO FLOPs) plus a
    carried-state ("inter") contribution so chunked prefill is exact.
    state = {"C": [B,H,Dh,Dh] f32, "n": [B,H,Dh] f32, "m": [B,H] f32,
             "conv": [B, 3, di], "logf_acc": unused} or None.
    """
    B, S, d_model = x.shape
    H = num_heads
    di = 2 * d_model
    Dh = di // H

    xz = x @ params["up_proj"]
    xm, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _mamba_conv_full(xm, params["conv_w"], params["conv_b"], conv_state)

    q = (xc @ params["wq"]).reshape(B, S, H, Dh)
    k = (xc @ params["wk"]).reshape(B, S, H, Dh) / math.sqrt(Dh)
    v = (xm @ params["wv"]).reshape(B, S, H, Dh)
    gates = (xm @ params["w_gates"]).astype(jnp.float32) + params["gate_bias"][None, None, :]
    log_i = gates[..., :H]                          # [B,S,H]
    log_f = jax.nn.log_sigmoid(gates[..., H:])      # [B,S,H]

    # Inclusive cumulative log-forget within this chunk.
    F = jnp.cumsum(log_f, axis=1)                   # [B,S,H]
    if state is None:
        C0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)
        n0 = jnp.zeros((B, H, Dh), jnp.float32)
        m0 = jnp.full((B, H), NEG_INF, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    block = min(block, S)
    while S % block:  # largest divisor of S not exceeding the requested block
        block -= 1
    nt = S // block
    qt = q.reshape(B, nt, block, H, Dh)
    kt = k.reshape(B, nt, block, H, Dh)
    vt = v.reshape(B, nt, block, H, Dh)
    Ft = F.reshape(B, nt, block, H)
    lit = log_i.reshape(B, nt, block, H)

    out_tiles = []
    # Running row state across kv tiles, per q tile: handled tile-by-tile.
    for i in range(nt):
        F_i = Ft[:, i].transpose(0, 2, 1)           # [B,H,bq]
        q_i = qt[:, i]
        # start from the inter-chunk (carried-state) contribution:
        #   e_inter = F_t + m0 ;  val = q_t · C0 ; norm = q_t · n0
        m_row = F_i + m0[..., None]                                  # [B,H,bq]
        acc = jnp.einsum("bqhd,bhde->bhqe", q_i, C0)                 # [B,H,bq,Dh]
        nrm = jnp.einsum("bqhd,bhd->bhq", q_i, n0)                   # [B,H,bq]
        if state is None:
            acc = jnp.zeros((B, H, block, Dh), jnp.float32)
            nrm = jnp.zeros((B, H, block), jnp.float32)
        for j in range(i + 1):
            e = (
                F_i[..., :, None]
                - Ft[:, j].transpose(0, 2, 1)[..., None, :]
                + lit[:, j].transpose(0, 2, 1)[..., None, :]
            )  # [B,H,bq,bk]
            if i == j:
                tri = jnp.tril(jnp.ones((block, block), bool))
                e = jnp.where(tri[None, None], e, NEG_INF)
            m_new = jnp.maximum(m_row, jnp.max(e, axis=-1))
            d = jnp.exp(e - m_new[..., None])
            s = jnp.einsum("bqhd,bkhd->bhqk", q_i, kt[:, j],
                           preferred_element_type=jnp.float32) * d
            corr = jnp.exp(m_row - m_new)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", s, vt[:, j].astype(jnp.float32))
            nrm = nrm * corr + jnp.sum(s, axis=-1)
            m_row = m_new
        denom = jnp.maximum(jnp.abs(nrm), jnp.exp(-jnp.minimum(m_row, 30.0)))
        h_i = acc / jnp.maximum(denom, 1e-30)[..., None]             # [B,H,bq,Dh]
        out_tiles.append(h_i.transpose(0, 2, 1, 3).reshape(B, block, di))
    h = jnp.concatenate(out_tiles, axis=1) if nt > 1 else out_tiles[0]

    # ---- final carried state (one pass over tiles) --------------------------
    F_last = F[:, -1]                                                # [B,H]
    # candidates over all in-chunk s: F_last - F_s + logi_s
    cand = F_last[:, None, :] - F + log_i                            # [B,S,H]
    m_state = jnp.maximum(F_last + m0, jnp.max(cand, axis=1))        # [B,H]
    w = jnp.exp(cand - m_state[:, None, :])                          # [B,S,H]
    C_new = jnp.exp(F_last + m0 - m_state)[..., None, None] * C0 + jnp.einsum(
        "bsh,bshd,bshe->bhde", w, k.astype(jnp.float32), v.astype(jnp.float32))
    n_new = jnp.exp(F_last + m0 - m_state)[..., None] * n0 + jnp.einsum(
        "bsh,bshd->bhd", w, k.astype(jnp.float32))

    h = _group_norm_heads(h.astype(x.dtype), params["gn_scale"], H)
    out = (h * jax.nn.silu(z)) @ params["down_proj"]
    return out, {"C": C_new, "n": n_new, "m": m_state, "conv": new_conv}


def init_mlstm_state(B: int, d_model: int, num_heads: int, dtype) -> dict:
    di = 2 * d_model
    Dh = di // num_heads
    return {
        "C": jnp.zeros((B, num_heads, Dh, Dh), jnp.float32),
        "n": jnp.zeros((B, num_heads, Dh), jnp.float32),
        "m": jnp.full((B, num_heads), NEG_INF, jnp.float32),
        "conv": jnp.zeros((B, 3, di), dtype),
    }


def mlstm_decode(params, x: jnp.ndarray, state: dict, num_heads: int
                 ) -> Tuple[jnp.ndarray, dict]:
    """Single-token mLSTM step. x: [B, 1, d_model]."""
    out, new_state = mlstm_mix(params, x, state, num_heads, block=1)
    return out, new_state


# =============================================================================
# xLSTM sLSTM (scalar memory, recurrent gate feedback -> sequential)
# =============================================================================
def init_slstm(key, d_model: int, num_heads: int, dtype):
    Dh = d_model // num_heads
    ks = split(key, 4)
    ff = ((4 * d_model // 3) + 63) // 64 * 64
    return {
        "w_gates": dense_init(ks[0], d_model, 4 * d_model, dtype),  # z,i,f,o
        "r_gates": (jax.random.normal(ks[1], (num_heads, Dh, 4 * Dh), jnp.float32)
                    / math.sqrt(Dh)).astype(dtype),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((2 * d_model,)), jnp.ones((d_model,)) * 2.0, jnp.zeros((d_model,))]
        ).astype(jnp.float32),
        "gn_scale": jnp.zeros((d_model,), dtype),
        "up_proj": dense_init(ks[2], d_model, 2 * ff, dtype),
        "down_proj": dense_init(ks[3], ff, d_model, dtype),
    }


def slstm_mix(params, x: jnp.ndarray, state: Optional[dict], num_heads: int
              ) -> Tuple[jnp.ndarray, dict]:
    """sLSTM over [B, S, d]; sequential scan (inherent recurrence)."""
    B, S, d = x.shape
    H = num_heads
    Dh = d // H
    gx = (x @ params["w_gates"]).astype(jnp.float32) + params["gate_bias"]  # [B,S,4d]
    gx = gx.reshape(B, S, 4, H, Dh)

    if state is None:
        state = init_slstm_state(B, d, H, x.dtype)
    carry0 = (state["c"], state["n"], state["h"], state["m"])
    R = params["r_gates"].astype(jnp.float32)  # [H, Dh, 4Dh]

    def step(carry, g_t):
        c, n, h, m = carry                     # [B,H,Dh] x3, [B,H,Dh]
        gr = jnp.einsum("bhd,hde->bhe", h, R).reshape(B, H, 4, Dh).transpose(0, 2, 1, 3)
        g = g_t + gr                           # [B,4,H,Dh]
        z_t = jnp.tanh(g[:, 0])
        i_t = g[:, 1]
        f_t = jax.nn.log_sigmoid(g[:, 2])
        o_t = jax.nn.sigmoid(g[:, 3])
        m_new = jnp.maximum(f_t + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(f_t + m - m_new)
        c = f_p * c + i_p * z_t
        n = f_p * n + i_p
        h_new = o_t * (c / jnp.maximum(n, 1e-6))
        return (c, n, h_new, m_new), h_new

    (cT, nT, hT, mT), ys = jax.lax.scan(step, carry0, gx.transpose(1, 0, 2, 3, 4))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    y = _group_norm_heads(y, params["gn_scale"], H)
    up = y @ params["up_proj"]
    a, b = jnp.split(up, 2, axis=-1)
    out = (jax.nn.gelu(a, approximate=True) * b) @ params["down_proj"]
    return out, {"c": cT, "n": nT, "h": hT, "m": mT}


def init_slstm_state(B: int, d_model: int, num_heads: int, dtype) -> dict:
    Dh = d_model // num_heads
    z = lambda: jnp.zeros((B, num_heads, Dh), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": z()}
