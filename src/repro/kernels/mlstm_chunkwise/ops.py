"""Jitted public wrapper for the mLSTM chunkwise kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mlstm_chunkwise.kernel import mlstm_chunkwise


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunkwise_op(q, k, v, log_i, log_f, *, chunk=128, interpret=False):
    return mlstm_chunkwise(q, k, v, log_i, log_f, chunk=chunk,
                           interpret=interpret)
