"""Pure-jnp oracle for the mLSTM chunkwise kernel: the stabilized quadratic
(parallel) form over the full sequence (xLSTM paper, appendix)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mlstm_ref(q, k, v, log_i, log_f):
    """q/k/v: [B, H, S, D]; log_i/log_f: [B, H, S]."""
    B, H, S, D = q.shape
    F = jnp.cumsum(log_f.astype(jnp.float32), axis=-1)       # [B,H,S]
    e = F[..., :, None] - F[..., None, :] + log_i.astype(jnp.float32)[..., None, :]
    tri = jnp.tril(jnp.ones((S, S), bool))
    e = jnp.where(tri, e, NEG_INF)
    m = jnp.max(e, axis=-1)                                   # [B,H,S]
    d = jnp.exp(e - m[..., None])
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * d
    nrm = jnp.maximum(jnp.abs(jnp.sum(s, axis=-1)),
                      jnp.exp(-jnp.minimum(m, 30.0)))
    out = jnp.einsum("bhqk,bhkd->bhqd", s, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return (out / jnp.maximum(nrm, 1e-30)[..., None]).astype(q.dtype)
