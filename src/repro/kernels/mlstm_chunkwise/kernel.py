"""xLSTM mLSTM chunkwise-parallel Pallas TPU kernel.

The mLSTM cell keeps a matrix memory C_t = f_t C_{t-1} + i_t v_t k_t^T with
scalar-per-head gates, which admits the chunkwise form: within a chunk the
output is an attention-like product with a log-gate decay matrix (MXU
matmuls); across chunks a stabilized (C, n, m) state is carried.

TPU mapping: grid ``(B*H, num_chunks)`` with the chunk axis sequential; the
carried state ``C [D, D], n [D], m [1]`` lives in fp32 VMEM scratch. All four
within-chunk products ([c,D]x[D,c], [c,c]x[c,D], [c,D]x[D,D]) are
MXU-aligned when c and D are multiples of 128 (the xlstm-125m head dim 384 =
3x128 tiles). Stabilizers follow the xLSTM paper: row-max m over the decay
logits, denominator max(|n.q|, exp(-m)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, li_ref, lf_ref,   # [1,c,D]x3, [1,c]x2
            o_ref,                                 # [1,c,D]
            C_ref, n_ref, m_ref,                   # scratch [D,D],[D],[1]
            *, chunk: int):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)

    q = q_ref[0]                                   # [c, D]
    k = k_ref[0]
    v = v_ref[0]
    log_i = li_ref[0].astype(jnp.float32)          # [c]
    log_f = lf_ref[0].astype(jnp.float32)          # [c]

    F = jnp.cumsum(log_f)                          # inclusive in-chunk decay
    m0 = m_ref[0]

    # --- row stabilizer: max over inter (F_t + m0) and intra (F_t - F_s + i_s)
    e = F[:, None] - F[None, :] + log_i[None, :]   # [c, c]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    e = jnp.where(tri, e, NEG_INF)
    m_row = jnp.maximum(F + m0, jnp.max(e, axis=1))  # [c]

    # --- inter-chunk contribution (carried state)
    inter_scale = jnp.exp(F + m0 - m_row)          # [c]
    acc = jax.lax.dot_general(q, C_ref[...], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    acc = acc * inter_scale[:, None]               # [c, D]
    nrm = (q.astype(jnp.float32) @ n_ref[...]) * inter_scale  # [c]

    # --- intra-chunk (attention-like with decay weights)
    d_mat = jnp.exp(e - m_row[:, None])            # [c, c]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * d_mat
    acc = acc + jax.lax.dot_general(s.astype(v.dtype), v,
                                    (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    nrm = nrm + jnp.sum(s, axis=1)

    denom = jnp.maximum(jnp.abs(nrm), jnp.exp(-jnp.minimum(m_row, 30.0)))
    o_ref[0] = (acc / jnp.maximum(denom, 1e-30)[:, None]).astype(o_ref.dtype)

    # --- carry state to the next chunk
    F_last = F[chunk - 1]
    cand = F_last - F + log_i                      # [c]
    m_new = jnp.maximum(F_last + m0, jnp.max(cand))
    w = jnp.exp(cand - m_new)                      # [c]
    decay = jnp.exp(F_last + m0 - m_new)
    kw = k.astype(jnp.float32) * w[:, None]
    C_ref[...] = decay * C_ref[...] + jax.lax.dot_general(
        kw, v.astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_ref[...] = decay * n_ref[...] + jnp.sum(kw, axis=0)
    m_ref[0] = m_new


def mlstm_chunkwise(
    q: jnp.ndarray,      # [B, H, S, D] (k pre-scaled by 1/sqrt(D) upstream)
    k: jnp.ndarray,
    v: jnp.ndarray,
    log_i: jnp.ndarray,  # [B, H, S] input-gate logits
    log_f: jnp.ndarray,  # [B, H, S] log-sigmoid forget gates
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, S, D = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, S, D)
    vf = v.reshape(B * H, S, D)
    lif = log_i.reshape(B * H, S)
    lff = log_f.reshape(B * H, S)

    kernel = functools.partial(_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, D), lambda h, cj: (h, cj, 0)),
            pl.BlockSpec((1, chunk, D), lambda h, cj: (h, cj, 0)),
            pl.BlockSpec((1, chunk, D), lambda h, cj: (h, cj, 0)),
            pl.BlockSpec((1, chunk), lambda h, cj: (h, cj)),
            pl.BlockSpec((1, chunk), lambda h, cj: (h, cj)),
        ],
        out_specs=pl.BlockSpec((1, chunk, D), lambda h, cj: (h, cj, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((D, D), jnp.float32),
            pltpu.VMEM((D,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, lif, lff)
    return out.reshape(B, H, S, D)
