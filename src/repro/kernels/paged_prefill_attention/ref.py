"""Pure-jnp oracle for the paged-prefill attention kernel.

Matches the pre-kernel engine path bit-for-bit on CPU: gather each row's
logical KV view from the physical pages (``gather_pages``) and run exactly
the dense masked-softmax math the serving engine's ``_chunk_attend`` used,
op for op. The Pallas kernel is validated against this oracle to fp32
tolerance; the slot-vs-paged engine equivalence suite rides on the oracle
being bit-identical to the legacy path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import NEG_INF, gather_pages


def paged_prefill_attention_ref(
    q: jnp.ndarray,             # [R, Sq, Hkv, G, D] chunk queries
    k_pages: jnp.ndarray,       # [Hkv, P, ps, D] physical pages
    v_pages: jnp.ndarray,       # [Hkv, P, ps, D]
    block_tables: jnp.ndarray,  # [R, n] logical->physical page map
    row_pos: jnp.ndarray,       # [R] cache offset of each row's chunk
    lengths: jnp.ndarray,       # [R] post-chunk valid kv length per row
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Returns [R, Sq, Hkv, G, D]. Row t of row r attends to key positions
    ``k <= row_pos[r] + t`` (causal at the row's own offset), clipped to
    ``k < lengths[r]`` and the sliding window; padding rows (lengths == 0)
    produce garbage the caller discards."""
    Sq = q.shape[1]
    k_all = gather_pages(k_pages, block_tables)     # [R, n*ps, Hkv, D]
    v_all = gather_pages(v_pages, block_tables)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k_all,
                   preferred_element_type=jnp.float32) * scale
    if softcap and softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    Sk = k_all.shape[1]
    k_pos = jnp.arange(Sk)
    q_pos = jnp.asarray(row_pos).reshape(-1, 1) + jnp.arange(Sq)[None, :]
    mask = k_pos[None, None, :] <= q_pos[:, :, None]          # [R, Sq, Sk]
    if window and window > 0:
        mask = mask & (q_pos[:, :, None] - k_pos[None, None, :] < window)
    mask = mask & (k_pos[None, None, :]
                   < jnp.asarray(lengths).reshape(-1, 1, 1))
    mask = mask[:, None, None]                                # [R,1,1,Sq,Sk]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_all.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v_all)
