"""Pure-jnp oracles for the paged-prefill attention kernel.

Thin wrappers over :mod:`repro.kernels.ref_common`. The split-layout oracle
matches the pre-kernel engine path bit-for-bit on CPU: gather each row's
logical KV view from the physical pages and run exactly the dense
masked-softmax math the serving engine's ``_chunk_attend`` used, op for op —
the slot-vs-paged engine equivalence suite rides on that staying bitwise
stable. The fused-layout and partial variants reuse the same shared math, so
they are written once for both kernels.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref_common as rc
from repro.kernels.ref_common import NEG_INF  # re-export (legacy import site)


def _prefill_masked_scores(q, k_pages, block_tables, row_pos, lengths, *,
                           scale, window, softcap):
    k_all = rc.gather_rows(k_pages, block_tables)   # [R, n*ps, Hkv, D]
    s = rc.prefill_scores(q, k_all, scale=scale, softcap=softcap)
    return rc.prefill_mask(s, row_pos, lengths, window=window,
                           k_pos=jnp.arange(k_all.shape[1]), Sq=q.shape[1])


def paged_prefill_attention_ref(
    q: jnp.ndarray,             # [R, Sq, Hkv, G, D] chunk queries
    k_pages: jnp.ndarray,       # [Hkv, P, ps, D] physical pages
    v_pages: jnp.ndarray,       # [Hkv, P, ps, D]
    block_tables: jnp.ndarray,  # [R, n] logical->physical page map
    row_pos: jnp.ndarray,       # [R] cache offset of each row's chunk
    lengths: jnp.ndarray,       # [R] post-chunk valid kv length per row
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Returns [R, Sq, Hkv, G, D]. Row t of row r attends to key positions
    ``k <= row_pos[r] + t`` (causal at the row's own offset), clipped to
    ``k < lengths[r]`` and the sliding window; padding rows (lengths == 0)
    produce garbage the caller discards."""
    s = _prefill_masked_scores(q, k_pages, block_tables, row_pos, lengths,
                               scale=scale, window=window, softcap=softcap)
    v_all = rc.gather_rows(v_pages, block_tables)
    return rc.prefill_softmax_v(s, v_all)


def paged_prefill_attention_fused_ref(q, kv_pages, block_tables, row_pos,
                                      lengths, *, scale, window=0,
                                      softcap=0.0):
    """Fused head-interleaved layout (kv_pages [Hkv, P, 2, ps, D]); output
    bit-identical to ``paged_prefill_attention_ref`` on equivalent split
    pools."""
    k_pages, v_pages = rc.split_fused(kv_pages)
    return paged_prefill_attention_ref(q, k_pages, v_pages, block_tables,
                                       row_pos, lengths, scale=scale,
                                       window=window, softcap=softcap)


def paged_prefill_attention_partial_ref(q, kv_pages, block_tables, row_pos,
                                        lengths, *, scale, window=0,
                                        softcap=0.0):
    """Partial-softmax oracle over the fused layout: un-normalized flash
    state ``(acc [R,Sq,Hkv,G,D] f32, m [R,Sq,Hkv,G] f32, l [R,Sq,Hkv,G]
    f32)``. ``row_pos``/``lengths`` may be shard-local (global minus the
    shard's key offset): every mask term depends only on position
    differences, so the sequence-sharded fallback passes local offsets and
    the global semantics fall out."""
    k_pages, v_pages = rc.split_fused(kv_pages)
    s = _prefill_masked_scores(q, k_pages, block_tables, row_pos, lengths,
                               scale=scale, window=window, softcap=softcap)
    v_all = rc.gather_rows(v_pages, block_tables)
    return rc.prefill_partials(s, v_all)
