"""Paged-prefill (ragged chunked-prefill) attention Pallas TPU kernels.

Each batch row is a *chunk* of a different request's prompt, sitting at its
own cache offset ``row_pos[r]``, attending over that request's paged KV
(physical pages of ``page_size`` tokens indexed through a per-row block
table). This is the fused ragged mixed-batch shape the serving engine's
scheduler emits; computing it directly over the block tables removes the
dense ``gather_pages`` materialization (O(R*S*H*D) HBM traffic per layer)
and the [R, H, G, Sq, Sk] score tensor of the jnp path.

Two generations live here (mirroring ``paged_attention/kernel.py``):

* ``paged_prefill_attention`` — the original split-layout kernel (separate
  K/V pools, page axis in the grid, DMA left to the implicit Pallas grid
  pipeline). Kept as the layout/DMA A/B baseline for ``bench_microkernels``.
* ``paged_prefill_attention_fused`` — the production kernel over the fused
  head-interleaved pool ``[Hkv, P, 2, page_size, D]``: the pool stays in HBM
  (``ANY`` memory space), the page axis is an in-kernel loop bounded by the
  causal/window/length page range (pruned pages cost neither FLOPs *nor*
  DMA), and page copies ping-pong through a 2-deep VMEM scratch so the
  HBM→VMEM copy of page ``i+1`` overlaps the compute of page ``i`` — one
  DMA moving K and V together. ``partial=True`` emits the un-normalized
  flash state ``(acc, m, l)`` for the sequence-sharded mesh fallback;
  finalizing it reproduces ``partial=False`` bit-exactly.

TPU adaptation (vs. the CUDA chunked-prefill kernels vLLM drives):

* the block table, row offsets and row lengths are **scalar-prefetch**
  operands — the K/V BlockSpec index maps translate (row, logical page) ->
  physical page, so page gathers become ordinary prefetched VMEM tile loads
  (no pointer chasing on the compute path).
* grid ``(R, Hkv, num_q_tiles, num_pages)``; the page axis is innermost and
  sequential, so the online-softmax state (m, l, acc) for a q tile rides in
  VMEM scratch across pages; pages past ``ceil(len/page_size)`` or entirely
  above the causal diagonal / below the sliding window skip their FLOPs with
  ``pl.when``.
* GQA without KV repetition: q is laid out ``[R, Hkv, Sq*G, D]`` (grouped
  query heads interleaved per token), so each page is one
  [bq*G, D] x [D, page_size] MXU matmul per kv head and every KV page is
  streamed exactly once per (row, kv head).
* fp32 softmax state; matmuls accumulate fp32 via ``preferred_element_type``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, pos_ref, len_ref,      # scalar prefetch: [R,n],[R],[R]
            q_ref, k_ref, v_ref,           # [1,1,bq*G,D], [1,1,ps,D], [1,1,ps,D]
            o_ref,                         # [1,1,bq*G,D]
            m_ref, l_ref, acc_ref,         # VMEM scratch [bq*G],[bq*G],[bq*G,D]
            *, scale: float, window: int, softcap: float,
            page_size: int, num_pages: int, block_q: int, group: int):
    r = pl.program_id(0)
    qi = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[r]
    pos = pos_ref[r]
    pages_needed = (length + page_size - 1) // page_size
    # causal pruning: q tile qi covers absolute positions
    # [pos + qi*bq, pos + (qi+1)*bq); page j covers keys [j*ps, (j+1)*ps).
    live = (j < pages_needed) & (j * page_size <= pos + (qi + 1) * block_q - 1)
    if window > 0:
        # window pruning: the lowest key any q row of this tile can see
        live &= (j + 1) * page_size - 1 >= pos + qi * block_q - window + 1

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0]                                  # [bq*G, D]
        k = k_ref[0, 0]                                  # [ps, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq*G, ps]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        t = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        q_pos = pos + qi * block_q + t
        k_pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (k_pos <= q_pos) & (k_pos < length)
        if window > 0:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == num_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_prefill_attention(
    q: jnp.ndarray,             # [R, Sq, Hkv, G, D] chunk queries
    k_pages: jnp.ndarray,       # [Hkv, P_total, page_size, D]
    v_pages: jnp.ndarray,       # [Hkv, P_total, page_size, D]
    block_tables: jnp.ndarray,  # [R, num_pages] int32
    row_pos: jnp.ndarray,       # [R] int32 cache offset per row
    lengths: jnp.ndarray,       # [R] int32 post-chunk valid length per row
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns [R, Sq, Hkv, G, D] (same contract as the jnp oracle)."""
    R, Sq, Hkv, G, D = q.shape
    _, _, page_size, _ = k_pages.shape
    num_pages = block_tables.shape[1]
    block_q = min(block_q, Sq)
    assert Sq % block_q == 0, (Sq, block_q)
    nq = Sq // block_q

    # [R, Hkv, Sq*G, D]: token t's G grouped heads are rows [t*G, (t+1)*G)
    qf = q.transpose(0, 2, 1, 3, 4).reshape(R, Hkv, Sq * G, D)

    kernel = functools.partial(
        _kernel, scale=scale, window=window, softcap=softcap,
        page_size=page_size, num_pages=num_pages, block_q=block_q, group=G)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(R, Hkv, nq, num_pages),
        in_specs=[
            pl.BlockSpec((1, 1, block_q * G, D),
                         lambda r, h, i, j, bt, pos, L: (r, h, i, 0)),
            pl.BlockSpec((1, 1, page_size, D),
                         lambda r, h, i, j, bt, pos, L: (h, bt[r, j], 0, 0)),
            pl.BlockSpec((1, 1, page_size, D),
                         lambda r, h, i, j, bt, pos, L: (h, bt[r, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q * G, D),
                               lambda r, h, i, j, bt, pos, L: (r, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q * G,), jnp.float32),
            pltpu.VMEM((block_q * G,), jnp.float32),
            pltpu.VMEM((block_q * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, Hkv, Sq * G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), row_pos.astype(jnp.int32),
      lengths.astype(jnp.int32), qf, k_pages, v_pages)
    return out.reshape(R, Hkv, Sq, G, D).transpose(0, 2, 1, 3, 4)


# =============================================================================
# fused head-interleaved layout + explicit double-buffered page DMA
# =============================================================================
K_IDX, V_IDX = 0, 1   # interleave positions inside a fused page


def _fused_kernel(bt_ref, pos_ref, len_ref,   # scalar prefetch [R,n],[R],[R]
                  q_ref, kv_hbm,              # [1,1,bq*G,D], [Hkv,P,2,ps,D]
                  *refs,                      # outputs, then (scratch, sem)
                  scale: float, window: int, softcap: float,
                  page_size: int, num_pages: int, block_q: int, group: int,
                  partial: bool, dma_depth: int):
    if partial:
        o_ref, m_out, l_out = refs[0], refs[1], refs[2]
        scratch, sem = refs[3], refs[4]
    else:
        o_ref, m_out, l_out = refs[0], None, None
        scratch, sem = refs[1], refs[2]
    r = pl.program_id(0)
    h = pl.program_id(1)
    qi = pl.program_id(2)
    BG, D = q_ref.shape[2], q_ref.shape[3]

    length = len_ref[r]
    pos = pos_ref[r]
    # live page range for this q tile: pages past ceil(len/ps), entirely
    # above the causal diagonal, or entirely below the sliding window are
    # never copied in at all (the grid-pipelined kernel only skipped their
    # FLOPs). ``pos``/``length`` may be shard-local (and negative): floor
    # division keeps the bounds exact either way.
    pages_needed = (length + page_size - 1) // page_size
    causal_hi = (pos + (qi + 1) * block_q - 1) // page_size + 1
    j_hi = jnp.minimum(jnp.minimum(pages_needed, causal_hi), num_pages)
    if window > 0:
        j_lo = jnp.maximum(
            (pos + qi * block_q - window + 1) // page_size, 0)
    else:
        j_lo = jnp.zeros_like(j_hi)
    j_lo = jnp.minimum(j_lo, jnp.maximum(j_hi, 0))

    def dma(slot, j):
        # one async copy moves the page's K and V planes together.
        return pltpu.make_async_copy(
            kv_hbm.at[h, bt_ref[r, j]], scratch.at[slot], sem.at[slot])

    # warmup: fill the ring — up to depth-1 copies in flight before the
    # loop's first wait (depth 2 reduces to the classic single ping).
    for i in range(dma_depth - 1):
        @pl.when(j_lo + i < j_hi)
        def _warmup(i=i):
            dma(jax.lax.rem(j_lo + i, dma_depth), j_lo + i).start()

    def body(j, carry):
        m_prev, l_prev, acc_prev = carry
        slot = jax.lax.rem(j, dma_depth)
        # overlap: start page j+depth-1's copy into the slot freed at
        # iteration j-1, then block on page j and compute while the ring's
        # depth-1 outstanding copies fly.
        nxt = j + dma_depth - 1
        @pl.when(nxt < j_hi)
        def _prefetch_next():
            dma(jax.lax.rem(nxt, dma_depth), nxt).start()
        dma(slot, j).wait()
        k = scratch[slot, K_IDX]                         # [ps, D]
        v = scratch[slot, V_IDX]
        q = q_ref[0, 0]                                  # [bq*G, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq*G, ps]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        t = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        q_pos = pos + qi * block_q + t
        k_pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (k_pos <= q_pos) & (k_pos < length)
        if window > 0:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1)
        acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(
        j_lo, j_hi, body,
        (jnp.full((BG,), NEG_INF, jnp.float32), jnp.zeros((BG,), jnp.float32),
         jnp.zeros((BG, D), jnp.float32)))
    if partial:
        o_ref[0, 0] = acc
        m_out[0, 0] = m
        l_out[0, 0] = l
    else:
        o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_prefill_attention_fused(
    q: jnp.ndarray,             # [R, Sq, Hkv, G, D] chunk queries
    kv_pages: jnp.ndarray,      # [Hkv, P_total, 2, page_size, D]
    block_tables: jnp.ndarray,  # [R, num_pages] int32
    row_pos: jnp.ndarray,       # [R] int32 cache offset per row
    lengths: jnp.ndarray,       # [R] int32 post-chunk valid length per row
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    partial: bool = False,
    dma_depth: int = 2,
    interpret: bool = False,
):
    """Fused-layout ragged chunked prefill with ring-buffered page DMA.

    ``dma_depth`` sets the VMEM page-copy ring depth: depth N keeps up to
    N-1 copies in flight behind the page being computed (2 = the classic
    ping-pong double buffer). Output is bit-identical across depths.

    ``partial=False`` returns ``[R, Sq, Hkv, G, D]`` (the oracle's contract).
    ``partial=True`` returns the un-normalized flash state
    ``(acc [R,Sq,Hkv,G,D] f32, m [R,Sq,Hkv,G] f32, l [R,Sq,Hkv,G] f32)``;
    ``row_pos``/``lengths`` may then be shard-local (global minus the
    shard's key offset) — every mask depends only on position differences.
    Finalizing the partials matches ``partial=False`` bit-exactly.
    """
    R, Sq, Hkv, G, D = q.shape
    _, _, two, page_size, _ = kv_pages.shape
    assert two == 2, kv_pages.shape
    assert dma_depth >= 2, dma_depth
    num_pages = block_tables.shape[1]
    block_q = min(block_q, Sq)
    assert Sq % block_q == 0, (Sq, block_q)
    nq = Sq // block_q

    # [R, Hkv, Sq*G, D]: token t's G grouped heads are rows [t*G, (t+1)*G)
    qf = q.transpose(0, 2, 1, 3, 4).reshape(R, Hkv, Sq * G, D)

    kernel = functools.partial(
        _fused_kernel, scale=scale, window=window, softcap=softcap,
        page_size=page_size, num_pages=num_pages, block_q=block_q, group=G,
        partial=partial, dma_depth=dma_depth)

    if partial:
        out_shape = (
            jax.ShapeDtypeStruct((R, Hkv, Sq * G, D), jnp.float32),
            jax.ShapeDtypeStruct((R, Hkv, Sq * G), jnp.float32),
            jax.ShapeDtypeStruct((R, Hkv, Sq * G), jnp.float32))
        out_specs = (
            pl.BlockSpec((1, 1, block_q * G, D),
                         lambda r, h, i, bt, pos, L: (r, h, i, 0)),
            pl.BlockSpec((1, 1, block_q * G),
                         lambda r, h, i, bt, pos, L: (r, h, i)),
            pl.BlockSpec((1, 1, block_q * G),
                         lambda r, h, i, bt, pos, L: (r, h, i)),
        )
    else:
        out_shape = jax.ShapeDtypeStruct((R, Hkv, Sq * G, D), q.dtype)
        out_specs = pl.BlockSpec((1, 1, block_q * G, D),
                                 lambda r, h, i, bt, pos, L: (r, h, i, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(R, Hkv, nq),
        in_specs=[
            pl.BlockSpec((1, 1, block_q * G, D),
                         lambda r, h, i, bt, pos, L: (r, h, i, 0)),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((dma_depth, 2, page_size, D), kv_pages.dtype),
            pltpu.SemaphoreType.DMA((dma_depth,)),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_tables.astype(jnp.int32), row_pos.astype(jnp.int32),
      lengths.astype(jnp.int32), qf, kv_pages)

    def _rows(x):   # [R, Hkv, Sq*G, ...] -> [R, Sq, Hkv, G, ...]
        shp = (R, Hkv, Sq, G) + x.shape[3:]
        order = (0, 2, 1, 3) + tuple(range(4, len(shp)))
        return x.reshape(shp).transpose(order)

    if partial:
        acc, m, l = out
        return _rows(acc), _rows(m), _rows(l)
    return _rows(out)
