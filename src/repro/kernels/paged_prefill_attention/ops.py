"""Jitted public wrapper + sharded dispatch for the paged-prefill attention
kernel.

``paged_prefill_attention_auto`` mirrors the decode op's mesh dispatch (see
``kernels/paged_attention/ops.py``): single device exactly as before;
head-sharded ``shard_map`` when the KV head count divides the mesh axis (each
shard runs the unmodified kernel/oracle on its head slice, grid shrinking
with the slice); otherwise the sequence-sharded fallback — replicated pages,
block-table columns sharded, partial softmax combined flash-style with
``pmax``/``psum`` — using the jnp oracle math on every backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels.paged_prefill_attention.kernel import paged_prefill_attention
from repro.kernels.paged_prefill_attention.ref import (
    NEG_INF, paged_prefill_attention_ref)
from repro.kernels.shard_utils import axis_size, head_shards, shard_map


@functools.partial(jax.jit, static_argnames=("scale", "window", "softcap",
                                             "block_q", "interpret"))
def paged_prefill_attention_op(q, k_pages, v_pages, block_tables, row_pos,
                               lengths, *, scale, window=0, softcap=0.0,
                               block_q=128, interpret=False):
    return paged_prefill_attention(q, k_pages, v_pages, block_tables, row_pos,
                                   lengths, scale=scale, window=window,
                                   softcap=softcap, block_q=block_q,
                                   interpret=interpret)


def _single_device(q, k_pages, v_pages, block_tables, row_pos, lengths, *,
                   scale, window, softcap):
    """Backend dispatch on one shard/device: the Pallas TPU kernel on TPU
    (streams K/V pages once, no gathered k_all/v_all and no dense
    [R,H,G,Sq,Sk] score tensor), the pure-jnp oracle elsewhere (CPU CI
    boxes). Traceable either way — the choice is made at trace time."""
    if jax.default_backend() == "tpu":
        return paged_prefill_attention(q, k_pages, v_pages, block_tables,
                                       row_pos, lengths, scale=scale,
                                       window=window, softcap=softcap)
    return paged_prefill_attention_ref(q, k_pages, v_pages, block_tables,
                                       row_pos, lengths, scale=scale,
                                       window=window, softcap=softcap)


def _head_sharded(q, k_pages, v_pages, block_tables, row_pos, lengths, *,
                  scale, window, softcap, mesh, axis):
    """KV heads shard on ``axis``; q [R, Sq, Hkv, G, D] shards its Hkv dim in
    lockstep with the page pools, so per-head math is untouched and the
    output only needs one re-replicating all-gather (no arithmetic)."""
    def one_shard(q_, k_, v_, bt_, rp_, ln_):
        return _single_device(q_, k_, v_, bt_, rp_, ln_, scale=scale,
                              window=window, softcap=softcap)

    fn = shard_map(one_shard, mesh=mesh,
                   in_specs=(P(None, None, axis, None, None),
                             P(axis, None, None, None),
                             P(axis, None, None, None),
                             P(None, None), P(None), P(None)),
                   out_specs=P(None, None, axis, None, None))
    out = fn(q, k_pages, v_pages, block_tables, row_pos, lengths)
    return jax.lax.with_sharding_constraint(out, NamedSharding(mesh, P()))


def _seq_sharded(q, k_pages, v_pages, block_tables, row_pos, lengths, *,
                 scale, window, softcap, mesh, axis):
    """Replicated pages, block-table columns sharded: shard i attends its
    rows' queries over logical pages [i*n/m, (i+1)*n/m) and contributes a
    partial softmax. Mirrors ``paged_prefill_attention_ref`` term for term —
    only the cross-shard grouping of the sums differs."""
    m = axis_size(mesh, axis)
    R, Sq = q.shape[0], q.shape[1]
    ps = k_pages.shape[2]
    n = block_tables.shape[1]
    if n % m:
        pad = m - n % m            # page-0 pad columns land past every
        block_tables = jnp.concatenate(                 # row's valid length
            [block_tables, jnp.zeros((R, pad), block_tables.dtype)], axis=1)
        # pin replicated: a GSPMD-chosen partial sharding on the concat
        # output would be *summed* into the shard_map in_spec (see the
        # decode op for the observed failure mode).
        block_tables = jax.lax.with_sharding_constraint(
            block_tables, NamedSharding(mesh, P()))
    n_loc = block_tables.shape[1] // m

    def one_shard(q_, kp, vp, bt_, rp, ln):
        i = jax.lax.axis_index(axis)
        g = kp[:, bt_]                          # [Hkv, R, n_loc, ps, D]
        Hkv, _, _, _, D = g.shape
        k_all = g.transpose(1, 2, 3, 0, 4).reshape(R, n_loc * ps, Hkv, D)
        v_all = vp[:, bt_].transpose(1, 2, 3, 0, 4).reshape(
            R, n_loc * ps, Hkv, D)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_, k_all,
                       preferred_element_type=jnp.float32) * scale
        if softcap and softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = i * (n_loc * ps) + jnp.arange(n_loc * ps)   # global positions
        q_pos = jnp.asarray(rp).reshape(-1, 1) + jnp.arange(Sq)[None, :]
        mask = k_pos[None, None, :] <= q_pos[:, :, None]    # [R, Sq, k]
        if window and window > 0:
            mask = mask & (q_pos[:, :, None] - k_pos[None, None, :] < window)
        mask = mask & (k_pos[None, None, :]
                       < jnp.asarray(ln).reshape(-1, 1, 1))
        mask = mask[:, None, None]                          # [R,1,1,Sq,k]
        s = jnp.where(mask, s, NEG_INF)
        m_loc = jnp.max(s, axis=-1, keepdims=True)
        m_glob = jax.lax.pmax(m_loc, axis)      # exact: max is associative
        e = jnp.exp(s - m_glob)
        den = jax.lax.psum(jnp.sum(e, axis=-1, keepdims=True), axis)
        p = (e / den).astype(v_all.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_all)
        return jax.lax.psum(out, axis)

    fn = shard_map(one_shard, mesh=mesh,
                   in_specs=(P(), P(), P(), P(None, axis), P(), P()),
                   out_specs=P())
    return fn(q, k_pages, v_pages, block_tables, row_pos, lengths)


def paged_prefill_attention_auto(q, k_pages, v_pages, block_tables, row_pos,
                                 lengths, *, scale, window=0, softcap=0.0,
                                 mesh=None, axis="model"):
    """Mesh-aware dispatch used inside the model's paged-chunk forward (see
    module docstring). ``mesh=None`` (or a 1-wide ``axis``) is the exact
    pre-mesh single-device path."""
    m = axis_size(mesh, axis)
    if m <= 1:
        return _single_device(q, k_pages, v_pages, block_tables, row_pos,
                              lengths, scale=scale, window=window,
                              softcap=softcap)
    if head_shards(k_pages.shape[0], mesh, axis) > 1:
        return _head_sharded(q, k_pages, v_pages, block_tables, row_pos,
                             lengths, scale=scale, window=window,
                             softcap=softcap, mesh=mesh, axis=axis)
    return _seq_sharded(q, k_pages, v_pages, block_tables, row_pos, lengths,
                        scale=scale, window=window, softcap=softcap,
                        mesh=mesh, axis=axis)
