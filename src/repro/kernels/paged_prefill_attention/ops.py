"""Jitted public wrapper for the paged-prefill attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_prefill_attention.kernel import paged_prefill_attention
from repro.kernels.paged_prefill_attention.ref import paged_prefill_attention_ref


@functools.partial(jax.jit, static_argnames=("scale", "window", "softcap",
                                             "block_q", "interpret"))
def paged_prefill_attention_op(q, k_pages, v_pages, block_tables, row_pos,
                               lengths, *, scale, window=0, softcap=0.0,
                               block_q=128, interpret=False):
    return paged_prefill_attention(q, k_pages, v_pages, block_tables, row_pos,
                                   lengths, scale=scale, window=window,
                                   softcap=softcap, block_q=block_q,
                                   interpret=interpret)


def paged_prefill_attention_auto(q, k_pages, v_pages, block_tables, row_pos,
                                 lengths, *, scale, window=0, softcap=0.0):
    """Backend dispatch used inside the model's paged-chunk forward: the
    Pallas TPU kernel on TPU (streams K/V pages once, no gathered k_all/v_all
    and no dense [R,H,G,Sq,Sk] score tensor), the pure-jnp oracle elsewhere
    (CPU CI boxes). Traceable either way — the choice is made at trace time."""
    if jax.default_backend() == "tpu":
        return paged_prefill_attention(q, k_pages, v_pages, block_tables,
                                       row_pos, lengths, scale=scale,
                                       window=window, softcap=softcap)
    return paged_prefill_attention_ref(q, k_pages, v_pages, block_tables,
                                       row_pos, lengths, scale=scale,
                                       window=window, softcap=softcap)
