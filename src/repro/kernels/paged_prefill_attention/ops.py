"""Jitted public wrapper + sharded dispatch for the paged-prefill attention
kernel, over the fused head-interleaved KV pool ``[Hkv, P, 2, ps, D]``.

``paged_prefill_attention_auto`` mirrors the decode op's mesh dispatch (see
``kernels/paged_attention/ops.py``): single device exactly as before (the
fused double-buffered Pallas kernel on TPU, the jnp oracle on CPU);
head-sharded ``shard_map`` when the KV head count divides the mesh axis (each
shard runs the unmodified kernel/oracle on its head slice, grid shrinking
with the slice); otherwise the sequence-sharded fallback — replicated pages,
block-table columns sharded, each shard contributing un-normalized flash
state from the **partial-softmax kernel** (``partial=True`` on TPU, the jnp
partial oracle on CPU), combined flash-style with ``pmax``/``psum``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels.paged_attention.ops import dma_depth
from repro.kernels.paged_prefill_attention.kernel import (
    paged_prefill_attention, paged_prefill_attention_fused)
from repro.kernels.paged_prefill_attention.ref import (
    NEG_INF, paged_prefill_attention_fused_ref,
    paged_prefill_attention_partial_ref, paged_prefill_attention_ref)
from repro.kernels.shard_utils import axis_size, head_shards, shard_map


@functools.partial(jax.jit, static_argnames=("scale", "window", "softcap",
                                             "block_q", "interpret"))
def paged_prefill_attention_op(q, kv_pages, block_tables, row_pos,
                               lengths, *, scale, window=0, softcap=0.0,
                               block_q=128, interpret=False):
    return paged_prefill_attention_fused(
        q, kv_pages, block_tables, row_pos, lengths, scale=scale,
        window=window, softcap=softcap, block_q=block_q,
        dma_depth=dma_depth(), interpret=interpret)


def _single_device(q, kv_pages, block_tables, row_pos, lengths, *,
                   scale, window, softcap):
    """Backend dispatch on one shard/device: the fused ring-buffered
    Pallas TPU kernel on TPU (streams each K/V page once with one DMA, no
    gathered k_all/v_all and no dense [R,H,G,Sq,Sk] score tensor), the
    pure-jnp oracle elsewhere (CPU CI boxes). Traceable either way — the
    choice is made at trace time."""
    if jax.default_backend() == "tpu":
        return paged_prefill_attention_fused(q, kv_pages, block_tables,
                                             row_pos, lengths, scale=scale,
                                             window=window, softcap=softcap,
                                             dma_depth=dma_depth())
    return paged_prefill_attention_fused_ref(q, kv_pages, block_tables,
                                             row_pos, lengths, scale=scale,
                                             window=window, softcap=softcap)


def _partials(q, kv_pages, block_tables, row_pos, lengths, *, scale, window,
              softcap):
    """Per-shard un-normalized flash state (acc, m, l): the partial-softmax
    Pallas kernel on TPU, its jnp partial oracle elsewhere."""
    if jax.default_backend() == "tpu":
        return paged_prefill_attention_fused(
            q, kv_pages, block_tables, row_pos, lengths, scale=scale,
            window=window, softcap=softcap, partial=True,
            dma_depth=dma_depth())
    return paged_prefill_attention_partial_ref(
        q, kv_pages, block_tables, row_pos, lengths, scale=scale,
        window=window, softcap=softcap)


def _head_sharded(q, kv_pages, block_tables, row_pos, lengths, *,
                  scale, window, softcap, mesh, axis):
    """KV heads shard on ``axis``; q [R, Sq, Hkv, G, D] shards its Hkv dim in
    lockstep with the fused page pool, so per-head math is untouched and the
    output only needs one re-replicating all-gather (no arithmetic)."""
    def one_shard(q_, kv_, bt_, rp_, ln_):
        return _single_device(q_, kv_, bt_, rp_, ln_, scale=scale,
                              window=window, softcap=softcap)

    fn = shard_map(one_shard, mesh=mesh,
                   in_specs=(P(None, None, axis, None, None),
                             P(axis, None, None, None, None),
                             P(None, None), P(None), P(None)),
                   out_specs=P(None, None, axis, None, None))
    out = fn(q, kv_pages, block_tables, row_pos, lengths)
    return jax.lax.with_sharding_constraint(out, NamedSharding(mesh, P()))


def _seq_sharded(q, kv_pages, block_tables, row_pos, lengths, *,
                 scale, window, softcap, mesh, axis):
    """Replicated pages, block-table columns sharded: shard i attends its
    rows' queries over logical pages [i*n/m, (i+1)*n/m) and contributes the
    un-normalized flash state from the partial-softmax kernel/oracle (every
    mask term depends only on position differences, so shard-local
    ``row_pos - offset`` / ``lengths - offset`` carry the global
    semantics). The flash combine — ``pmax``/``psum`` — is the only
    cross-shard arithmetic."""
    m = axis_size(mesh, axis)
    R, Sq = q.shape[0], q.shape[1]
    ps = kv_pages.shape[3]
    n = block_tables.shape[1]
    if n % m:
        pad = m - n % m            # page-0 pad columns land past every
        block_tables = jnp.concatenate(                 # row's valid length
            [block_tables, jnp.zeros((R, pad), block_tables.dtype)], axis=1)
        # pin replicated: a GSPMD-chosen partial sharding on the concat
        # output would be *summed* into the shard_map in_spec (see the
        # decode op for the observed failure mode).
        block_tables = jax.lax.with_sharding_constraint(
            block_tables, NamedSharding(mesh, P()))
    n_loc = block_tables.shape[1] // m

    def one_shard(q_, kvp, bt_, rp, ln):
        i = jax.lax.axis_index(axis)
        off = i * (n_loc * ps)                  # shard's global key offset
        acc, mx, l = _partials(q_, kvp, bt_, rp - off, ln - off, scale=scale,
                               window=window, softcap=softcap)
        m_glob = jax.lax.pmax(mx, axis)         # exact: max is associative
        c = jnp.exp(mx - m_glob)
        num = jax.lax.psum(acc * c[..., None], axis)
        den = jax.lax.psum(l * c, axis)
        return (num / jnp.maximum(den, 1e-30)[..., None]).astype(q_.dtype)

    fn = shard_map(one_shard, mesh=mesh,
                   in_specs=(P(), P(), P(None, axis), P(), P()),
                   out_specs=P())
    return fn(q, kv_pages, block_tables, row_pos, lengths)


def paged_prefill_attention_auto(q, kv_pages, block_tables, row_pos,
                                 lengths, *, scale, window=0, softcap=0.0,
                                 mesh=None, axis="model"):
    """Mesh-aware dispatch used inside the model's paged-chunk forward (see
    module docstring). ``mesh=None`` (or a 1-wide ``axis``) is the exact
    pre-mesh single-device path."""
    m = axis_size(mesh, axis)
    if m <= 1:
        return _single_device(q, kv_pages, block_tables, row_pos,
                              lengths, scale=scale, window=window,
                              softcap=softcap)
    if head_shards(kv_pages.shape[0], mesh, axis) > 1:
        return _head_sharded(q, kv_pages, block_tables, row_pos,
                             lengths, scale=scale, window=window,
                             softcap=softcap, mesh=mesh, axis=axis)
    return _seq_sharded(q, kv_pages, block_tables, row_pos, lengths,
                        scale=scale, window=window, softcap=softcap,
                        mesh=mesh, axis=axis)
