"""Pallas TPU kernels for the serving hot paths.

The paper's contribution is scheduler-level, but chunked prefill and paged
decode are the compute the scheduler feeds; these kernels are the TPU-native
implementations (VMEM BlockSpec tiling, MXU-aligned tiles, fp32 online-softmax
state). Each kernel ships with an ``ops.py`` jit wrapper and a pure-jnp
oracle in ``ref.py``; CPU validation runs in ``interpret=True`` mode.

Kernels:
- ``chunked_prefill_attention`` — flash attention of a query chunk against
  cache prefix + itself (the exact shape chunked prefill creates).
- ``paged_attention`` — decode-time GQA attention over a block-table paged KV
  cache (scalar-prefetch indexed). ``paged_attention_fused`` is the serving
  generation: fused head-interleaved pool ``[Hkv, P, 2, ps, D]``, explicit
  double-buffered HBM→VMEM page DMA, and a ``partial=True`` mode emitting
  un-normalized flash state for the sequence-sharded mesh combine.
- ``paged_prefill_attention`` — ragged chunked-prefill attention computed
  *directly* over the paged KV (per-row block tables + offsets as
  scalar-prefetch operands), eliminating the dense page gather the jnp path
  needs. ``paged_prefill_attention_fused`` mirrors the decode kernel's fused
  layout / double-buffering / partials, plus per-(row, q-block) page-range
  pruning for causal and sliding-window masks.
- ``ref_common`` — the shared jnp oracle math both paged refs wrap (split
  and fused layouts, full softmax and partials, written once).
- ``mamba_scan`` — selective-state-space scan, chunked over sequence with a
  VMEM-carried state.
- ``mlstm_chunkwise`` — xLSTM matrix-memory cell, chunkwise-parallel form.
"""
