"""Chunked-prefill flash attention Pallas TPU kernel.

Computes attention of a query *chunk* (the tokens scheduled this iteration,
at sequence offset ``q_offset``) against the full KV buffer (cache prefix +
the chunk itself), with causal + sliding-window + valid-length masking.

TPU adaptation (vs. the CUDA flash kernels vLLM drives):

* grid ``(B*H, num_q_tiles, num_kv_tiles)`` — the last axis is innermost and
  sequential on TPU, so the online-softmax state (m, l, acc) lives in VMEM
  scratch and is carried across kv tiles; no atomics / warp shuffles needed.
* BlockSpec tiles ``(block_q, head_dim)`` / ``(block_k, head_dim)`` sized to
  MXU geometry (multiples of 128 on the matmul dims) and VMEM budget
  (~(bq + 2*bk) * D * 4B + bq*bk*4B per step).
* GQA without KV repetition: the kv BlockSpec index map folds the q-head ->
  kv-head mapping (``h // group``), so KV tiles are fetched once per kv head.
* fp32 softmax state; matmuls accumulate fp32 via ``preferred_element_type``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(lengths_ref,           # scalar prefetch: [B] valid kv lengths
            q_ref, k_ref, v_ref,   # [1, bq, D], [1, bk, D], [1, bk, D]
            o_ref,                 # [1, bq, D]
            m_ref, l_ref, acc_ref,  # VMEM scratch: [bq], [bq], [bq, D]
            *, scale: float, q_offset: int, causal: bool, window: int,
            softcap: float, block_q: int, block_k: int, num_kv_tiles: int,
            num_heads: int):
    h = pl.program_id(0)
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    b = h // num_heads

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                     # [bq, D]
    k = k_ref[0]                                     # [bk, D]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [bq, bk]
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < lengths_ref[b]
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == num_kv_tiles - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def chunked_prefill_attention(
    q: jnp.ndarray,        # [B, H, Sq, D]
    k: jnp.ndarray,        # [B, Hkv, Sk, D]
    v: jnp.ndarray,        # [B, Hkv, Sk, D]
    lengths: jnp.ndarray,  # [B] int32 valid kv lengths
    *,
    scale: float,
    q_offset: int = 0,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k

    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * Hkv, Sk, D)
    vf = v.reshape(B * Hkv, Sk, D)

    kernel = functools.partial(
        _kernel, scale=scale, q_offset=q_offset, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, num_kv_tiles=nk,
        num_heads=H)

    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, i, j, L: (h, i, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda h, i, j, L, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda h, i, j, L, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, i, j, L: (h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qf, kf, vf)
    return out.reshape(B, H, Sq, D)
