"""Jitted public wrapper for the chunked-prefill attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.chunked_prefill_attention.kernel import chunked_prefill_attention


@functools.partial(jax.jit, static_argnames=(
    "scale", "q_offset", "causal", "window", "softcap", "block_q", "block_k",
    "interpret"))
def chunked_prefill_attention_op(q, k, v, lengths, *, scale, q_offset=0,
                                 causal=True, window=0, softcap=0.0,
                                 block_q=128, block_k=128, interpret=False):
    return chunked_prefill_attention(
        q, k, v, lengths, scale=scale, q_offset=q_offset, causal=causal,
        window=window, softcap=softcap, block_q=block_q, block_k=block_k,
        interpret=interpret)
