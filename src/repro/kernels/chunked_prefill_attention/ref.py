"""Pure-jnp oracle for the chunked-prefill attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def chunked_prefill_attention_ref(q, k, v, lengths, *, scale, q_offset=0,
                                  causal=True, window=0, softcap=0.0):
    """q: [B, H, Sq, D]; k/v: [B, Hkv, Sk, D]; lengths: [B]."""
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    g = H // Hkv
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kr,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.broadcast_to(k_pos < lengths[:, None, None, None], s.shape)
    if causal:
        mask &= (q_pos >= k_pos)[None, None]
    if window > 0:
        mask &= (q_pos - k_pos < window)[None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vr.dtype), vr,
                      preferred_element_type=jnp.float32).astype(q.dtype)
