"""Paged-attention decode Pallas TPU kernels.

One new query token per sequence attends over a *paged* KV cache: physical
pages of ``page_size`` tokens indexed through a per-sequence block table
(vLLM's PagedAttention layout, §4 substrate).

Two generations live here:

* ``paged_attention`` — the original split-layout kernel (separate K and V
  pools, grid ``(B, Hkv, pages_per_seq)``, page DMA left to the implicit
  Pallas grid pipeline). Kept as the layout/DMA A/B baseline for
  ``bench_microkernels``.
* ``paged_attention_fused`` — the production kernel over the fused
  head-interleaved pool ``[Hkv, P, 2, page_size, D]`` (K at interleave 0,
  V at 1). The pool stays in HBM (``ANY`` memory space) and the kernel
  **double-buffers page DMA explicitly**: grid ``(B, Hkv)`` with the page
  axis as an in-kernel loop, ping-pong VMEM scratch ``[2, 2, ps, D]`` and a
  2-deep DMA semaphore array, so the HBM→VMEM copy of page ``i+1`` overlaps
  the flash-attention compute of page ``i`` — and one DMA moves K *and* V
  for a page (half the DMA count of the split layout).
  ``partial=True`` emits the un-normalized flash state ``(acc, m, l)``
  instead of dividing — the sequence-sharded mesh fallback combines those
  across shards (``pmax``/``psum``); finalizing the partials reproduces the
  full kernel's output bit-exactly (same loop, same final division).

Common TPU adaptations (vs. the CUDA kernel):

* block table + lengths are **scalar-prefetch** operands, so (sequence,
  logical page) -> physical page translation happens on the scalar core (no
  pointer chasing on the compute path, no per-warp gather).
* per-step compute is a [G, D] x [D, page_size] MXU matmul per kv head —
  decode is HBM-bound, and both kernels stream each KV page exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(block_tables_ref, lengths_ref,   # scalar prefetch
            q_ref, k_ref, v_ref,             # [1,1,G,D], [1,ps,D], [1,ps,D]
            o_ref,                           # [1,1,G,D]
            m_ref, l_ref, acc_ref,           # VMEM scratch [G],[G],[G,D]
            *, scale: float, window: int, softcap: float,
            page_size: int, num_pages: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    pages_needed = (length + page_size - 1) // page_size

    @pl.when(j < pages_needed)
    def _compute():
        q = q_ref[0, 0]                                  # [G, D]
        k = k_ref[0, 0]                                  # [ps, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [G, ps]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = k_pos < length
        if window > 0:
            mask &= k_pos >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == num_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_attention(
    q: jnp.ndarray,             # [B, H, D]
    k_pages: jnp.ndarray,       # [Hkv, P_total, page_size, D]
    v_pages: jnp.ndarray,       # [Hkv, P_total, page_size, D]
    block_tables: jnp.ndarray,  # [B, pages_per_seq] int32
    lengths: jnp.ndarray,       # [B] int32
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, D = q.shape
    Hkv, P_total, page_size, _ = k_pages.shape
    G = H // Hkv
    pages_per_seq = block_tables.shape[1]

    qg = q.reshape(B, Hkv, G, D)

    kernel = functools.partial(
        _kernel, scale=scale, window=window, softcap=softcap,
        page_size=page_size, num_pages=pages_per_seq)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, bt, L: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, D),
                         lambda b, h, j, bt, L: (h, bt[b, j], 0, 0)),
            pl.BlockSpec((1, 1, page_size, D),
                         lambda b, h, j, bt, L: (h, bt[b, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j, bt, L: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, H, D)


# =============================================================================
# fused head-interleaved layout + explicit double-buffered page DMA
# =============================================================================
K_IDX, V_IDX = 0, 1   # interleave positions inside a fused page


def _fused_kernel(bt_ref, len_ref,   # scalar prefetch: [B, n], [B]
                  q_ref, kv_hbm,     # [1,1,G,D] VMEM, [Hkv,P,2,ps,D] HBM
                  *refs,             # outputs, then (scratch, sem)
                  scale: float, window: int, softcap: float,
                  page_size: int, num_pages: int, partial: bool,
                  dma_depth: int):
    if partial:
        o_ref, m_out, l_out = refs[0], refs[1], refs[2]
        scratch, sem = refs[3], refs[4]
    else:
        o_ref, m_out, l_out = refs[0], None, None
        scratch, sem = refs[1], refs[2]
    b = pl.program_id(0)
    h = pl.program_id(1)
    G, D = q_ref.shape[2], q_ref.shape[3]

    length = len_ref[b]
    pages_needed = jnp.minimum(
        (length + page_size - 1) // page_size, num_pages)

    def dma(slot, j):
        # one async copy moves the page's K and V planes together (the
        # fused-layout win: half the DMA issue rate of split pools).
        return pltpu.make_async_copy(
            kv_hbm.at[h, bt_ref[b, j]], scratch.at[slot], sem.at[slot])

    # warmup: fill the ring — up to depth-1 copies in flight before the
    # loop's first wait (depth 2 reduces to the classic single ping).
    for i in range(dma_depth - 1):
        @pl.when(i < pages_needed)
        def _warmup(i=i):
            dma(i, i).start()

    def body(j, carry):
        m_prev, l_prev, acc_prev = carry
        slot = jax.lax.rem(j, dma_depth)
        # overlap: kick off page j+depth-1's HBM->VMEM copy into the slot
        # freed at iteration j-1, keeping depth-1 copies in flight while
        # page j computes.
        nxt = j + dma_depth - 1
        @pl.when(nxt < pages_needed)
        def _prefetch_next():
            dma(jax.lax.rem(nxt, dma_depth), nxt).start()
        dma(slot, j).wait()
        k = scratch[slot, K_IDX]                         # [ps, D]
        v = scratch[slot, V_IDX]
        q = q_ref[0, 0]                                  # [G, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [G, ps]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = k_pos < length
        if window > 0:
            mask &= k_pos >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1)
        acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(
        0, pages_needed, body,
        (jnp.full((G,), NEG_INF, jnp.float32), jnp.zeros((G,), jnp.float32),
         jnp.zeros((G, D), jnp.float32)))
    if partial:
        o_ref[0, 0] = acc
        m_out[0, 0] = m
        l_out[0, 0] = l
    else:
        o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_attention_fused(
    q: jnp.ndarray,             # [B, H, D]
    kv_pages: jnp.ndarray,      # [Hkv, P_total, 2, page_size, D]
    block_tables: jnp.ndarray,  # [B, pages_per_seq] int32
    lengths: jnp.ndarray,       # [B] int32 (may be shard-local, see below)
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
    partial: bool = False,
    dma_depth: int = 2,
    interpret: bool = False,
):
    """Fused-layout decode attention with ring-buffered page DMA.

    ``dma_depth`` sets the VMEM ring depth: depth N keeps up to N-1 page
    copies in flight behind the one being computed (2 = the classic
    ping-pong double buffer; deeper rings absorb burstier HBM latency at
    ``(N-2) * 2 * page_size * D`` extra VMEM per grid cell). Output is
    bit-identical across depths — only the copy schedule changes.

    ``partial=False`` returns ``[B, H, D]`` in q's dtype. ``partial=True``
    returns the un-normalized flash state ``(acc [B,H,D] f32, m [B,H] f32,
    l [B,H] f32)`` for the cross-shard flash-decode combine; ``lengths``
    may then be shard-local (global length minus the shard's key offset) —
    both masks depend only on ``length - k_pos``. Finalizing the partials
    (``acc / max(l, 1e-30)``) matches the ``partial=False`` output
    bit-exactly: same loop, same division.
    """
    B, H, D = q.shape
    Hkv, P_total, two, page_size, _ = kv_pages.shape
    assert two == 2, kv_pages.shape
    assert dma_depth >= 2, dma_depth
    G = H // Hkv
    pages_per_seq = block_tables.shape[1]

    qg = q.reshape(B, Hkv, G, D)

    kernel = functools.partial(
        _fused_kernel, scale=scale, window=window, softcap=softcap,
        page_size=page_size, num_pages=pages_per_seq, partial=partial,
        dma_depth=dma_depth)

    if partial:
        out_shape = (jax.ShapeDtypeStruct((B, Hkv, G, D), jnp.float32),
                     jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32),
                     jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32))
        out_specs = (
            pl.BlockSpec((1, 1, G, D), lambda b, h, bt, L: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, bt, L: (b, h, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, bt, L: (b, h, 0)),
        )
    else:
        out_shape = jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype)
        out_specs = pl.BlockSpec((1, 1, G, D),
                                 lambda b, h, bt, L: (b, h, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, bt, L: (b, h, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((dma_depth, 2, page_size, D), kv_pages.dtype),
            pltpu.SemaphoreType.DMA((dma_depth,)),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, kv_pages)
    if partial:
        acc, m, l = out
        return acc.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H)
    return out.reshape(B, H, D)
