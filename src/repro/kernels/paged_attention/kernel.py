"""Paged-attention decode Pallas TPU kernel.

One new query token per sequence attends over a *paged* KV cache: physical
pages of ``page_size`` tokens indexed through a per-sequence block table
(vLLM's PagedAttention layout, §4 substrate).

TPU adaptation (vs. the CUDA kernel):

* the block table is a **scalar-prefetch** operand — BlockSpec index maps read
  it to translate (sequence, logical page) -> physical page, so page gathers
  become ordinary prefetched VMEM tile loads (no pointer chasing on the
  compute path, no per-warp gather).
* grid ``(B, Hkv, pages_per_seq)``; the page axis is innermost/sequential, so
  the online-softmax state for the G grouped query heads rides in VMEM
  scratch, and pages past ``ceil(len/page_size)`` skip their FLOPs with
  ``pl.when`` (their DMA is position-masked out anyway).
* per-step compute is a [G, D] x [D, page_size] MXU matmul per kv head —
  decode is HBM-bound, and this layout streams each KV page exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(block_tables_ref, lengths_ref,   # scalar prefetch
            q_ref, k_ref, v_ref,             # [1,1,G,D], [1,ps,D], [1,ps,D]
            o_ref,                           # [1,1,G,D]
            m_ref, l_ref, acc_ref,           # VMEM scratch [G],[G],[G,D]
            *, scale: float, window: int, softcap: float,
            page_size: int, num_pages: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    pages_needed = (length + page_size - 1) // page_size

    @pl.when(j < pages_needed)
    def _compute():
        q = q_ref[0, 0]                                  # [G, D]
        k = k_ref[0, 0]                                  # [ps, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [G, ps]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = k_pos < length
        if window > 0:
            mask &= k_pos >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == num_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_attention(
    q: jnp.ndarray,             # [B, H, D]
    k_pages: jnp.ndarray,       # [Hkv, P_total, page_size, D]
    v_pages: jnp.ndarray,       # [Hkv, P_total, page_size, D]
    block_tables: jnp.ndarray,  # [B, pages_per_seq] int32
    lengths: jnp.ndarray,       # [B] int32
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, D = q.shape
    Hkv, P_total, page_size, _ = k_pages.shape
    G = H // Hkv
    pages_per_seq = block_tables.shape[1]

    qg = q.reshape(B, Hkv, G, D)

    kernel = functools.partial(
        _kernel, scale=scale, window=window, softcap=softcap,
        page_size=page_size, num_pages=pages_per_seq)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, bt, L: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, D),
                         lambda b, h, j, bt, L: (h, bt[b, j], 0, 0)),
            pl.BlockSpec((1, 1, page_size, D),
                         lambda b, h, j, bt, L: (h, bt[b, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j, bt, L: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, H, D)
