"""Pure-jnp oracles for the paged-attention decode kernel.

Thin wrappers over :mod:`repro.kernels.ref_common` (the shared gather-pages +
masked-softmax reference): the split-layout oracle, the fused head-interleaved
layout oracle, and the partial-softmax oracles the sequence-sharded mesh
fallback combines across shards. The split oracle's operations are unchanged
bit-for-bit from the pre-refactor module — engine slot-vs-paged equivalence
and greedy-token bit-identity ride on that.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref_common as rc
from repro.kernels.ref_common import NEG_INF  # re-export (legacy import site)


def _decode_masked_scores(q, k_pages, block_tables, lengths, *, scale,
                          window, softcap):
    k_seq = rc.gather_seq(k_pages, block_tables)
    s = rc.decode_scores(q, k_seq, scale=scale, softcap=softcap)
    return rc.decode_mask(s, lengths, window=window,
                          k_pos=jnp.arange(k_seq.shape[2]))


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        scale, window=0, softcap=0.0):
    """q: [B, H, D]; pages: [Hkv, P, ps, D]; block_tables: [B, n]; lengths [B]."""
    s = _decode_masked_scores(q, k_pages, block_tables, lengths, scale=scale,
                              window=window, softcap=softcap)
    v_seq = rc.gather_seq(v_pages, block_tables)
    return rc.decode_softmax_v(s, v_seq, q.dtype)


def paged_attention_fused_ref(q, kv_pages, block_tables, lengths, *,
                              scale, window=0, softcap=0.0):
    """Fused head-interleaved layout: kv_pages [Hkv, P, 2, ps, D] with K at
    interleave 0, V at 1. Same math as the split oracle — the layout only
    moves bytes, so outputs are bit-identical to ``paged_attention_ref`` on
    the equivalent split pools."""
    k_pages, v_pages = rc.split_fused(kv_pages)
    return paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                               scale=scale, window=window, softcap=softcap)


def paged_attention_partial_ref(q, kv_pages, block_tables, lengths, *,
                                scale, window=0, softcap=0.0):
    """Partial-softmax oracle over the fused layout: returns the
    un-normalized flash state ``(acc [B,H,D] f32, m [B,H] f32, l [B,H] f32)``.
    ``lengths`` may be shard-local (global length minus the shard's key
    offset) — both masks depend only on ``length - k_pos``, so the
    sequence-sharded fallback passes local lengths and global semantics fall
    out. ``rc.finalize_partials(acc, l, q.dtype)`` equals the full oracle up
    to the flash regrouping of the exp sums."""
    k_pages, v_pages = rc.split_fused(kv_pages)
    s = _decode_masked_scores(q, k_pages, block_tables, lengths, scale=scale,
                              window=window, softcap=softcap)
    v_seq = rc.gather_seq(v_pages, block_tables)
    return rc.decode_partials(s, v_seq)
