"""Pure-jnp oracle for the paged-attention decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        scale, window=0, softcap=0.0):
    """q: [B, H, D]; pages: [Hkv, P, ps, D]; block_tables: [B, n]; lengths [B]."""
    B, H, D = q.shape
    Hkv, _, ps, _ = k_pages.shape
    G = H // Hkv
    n = block_tables.shape[1]
    # gather each sequence's logical KV [B, Hkv, n*ps, D]
    k_seq = k_pages[:, block_tables]            # [Hkv, B, n, ps, D]
    v_seq = v_pages[:, block_tables]
    k_seq = k_seq.transpose(1, 0, 2, 3, 4).reshape(B, Hkv, n * ps, D)
    v_seq = v_seq.transpose(1, 0, 2, 3, 4).reshape(B, Hkv, n * ps, D)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_seq,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = jnp.arange(n * ps)
    mask = k_pos[None, None, None, :] < lengths[:, None, None, None]
    if window > 0:
        mask &= k_pos[None, None, None, :] >= (lengths - window)[:, None, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p.astype(v_seq.dtype), v_seq,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, D).astype(q.dtype)
