"""Jitted public wrapper for the paged-attention decode kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("scale", "window", "softcap",
                                             "interpret"))
def paged_attention_op(q, k_pages, v_pages, block_tables, lengths, *, scale,
                       window=0, softcap=0.0, interpret=False):
    return paged_attention(q, k_pages, v_pages, block_tables, lengths,
                           scale=scale, window=window, softcap=softcap,
                           interpret=interpret)


def paged_attention_auto(q, k_pages, v_pages, block_tables, lengths, *, scale,
                         window=0, softcap=0.0):
    """Backend dispatch used inside the model's paged-decode forward: the
    Pallas TPU kernel on TPU, the pure-jnp oracle elsewhere (CPU CI boxes).
    Traceable either way — the choice is made at trace time."""
    if jax.default_backend() == "tpu":
        return paged_attention(q, k_pages, v_pages, block_tables, lengths,
                               scale=scale, window=window, softcap=softcap)
    return paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                               scale=scale, window=window, softcap=softcap)
