"""Jitted public wrapper + sharded dispatch for the paged-attention decode
kernel, over the fused head-interleaved KV pool ``[Hkv, P, 2, ps, D]``.

``paged_attention_auto`` is the serving engine's entry point. Single device
(``mesh=None`` or a 1-wide axis) dispatches exactly as before: the fused
double-buffered Pallas TPU kernel on TPU, the pure-jnp oracle elsewhere. On a
mesh it runs under ``shard_map``:

* **head-sharded** (KV head count divides the axis): every shard holds a head
  slice of the fused page pool and runs the unmodified kernel/oracle on its
  slice — the kernel grid shrinks with the per-shard head count and no
  collective touches the softmax. The [B, H, D] output is re-replicated with
  one all-gather (pure data movement), so downstream replicated math is
  bit-identical to the single-device program.
* **sequence-sharded fallback** (heads don't divide — mirroring
  ``launch/sharding.py``'s KV cache rule): pages stay replicated and each
  shard attends over a column slice of the block tables with the
  **partial-softmax kernel** (``paged_attention_fused(partial=True)`` on
  TPU, its jnp partial oracle on CPU CI boxes), emitting un-normalized flash
  state ``(acc, m, l)``. The flash-decode combine stays collective-side:
  global ``pmax`` of the row maxima, ``psum`` of the rescaled normalizer and
  value partials, one division at the end — the jnp oracle is now only the
  test reference for this path.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels.paged_attention.kernel import (
    paged_attention, paged_attention_fused)
from repro.kernels.paged_attention.ref import (
    NEG_INF, paged_attention_fused_ref, paged_attention_partial_ref,
    paged_attention_ref)
from repro.kernels.shard_utils import axis_size, head_shards, shard_map


def dma_depth() -> int:
    """Page-DMA ring depth for the fused kernels (``REPRO_DMA_DEPTH``,
    default 2 = classic double buffer). Bit-identical across depths —
    deeper rings only trade VMEM for HBM-latency tolerance."""
    return max(2, int(os.environ.get("REPRO_DMA_DEPTH", "2")))


@functools.partial(jax.jit, static_argnames=("scale", "window", "softcap",
                                             "interpret"))
def paged_attention_op(q, kv_pages, block_tables, lengths, *, scale,
                       window=0, softcap=0.0, interpret=False):
    return paged_attention_fused(q, kv_pages, block_tables, lengths,
                                 scale=scale, window=window, softcap=softcap,
                                 dma_depth=dma_depth(), interpret=interpret)


def _single_device(q, kv_pages, block_tables, lengths, *, scale,
                   window, softcap):
    """Backend dispatch on one shard/device: the fused ring-buffered Pallas
    TPU kernel on TPU, the pure-jnp oracle elsewhere (CPU CI boxes).
    Traceable either way — the choice is made at trace time."""
    if jax.default_backend() == "tpu":
        return paged_attention_fused(q, kv_pages, block_tables, lengths,
                                     scale=scale, window=window,
                                     softcap=softcap, dma_depth=dma_depth())
    return paged_attention_fused_ref(q, kv_pages, block_tables, lengths,
                                     scale=scale, window=window,
                                     softcap=softcap)


def _partials(q, kv_pages, block_tables, lengths, *, scale, window, softcap):
    """Per-shard un-normalized flash state (acc, m, l): the partial-softmax
    Pallas kernel on TPU, its jnp partial oracle elsewhere."""
    if jax.default_backend() == "tpu":
        return paged_attention_fused(q, kv_pages, block_tables, lengths,
                                     scale=scale, window=window,
                                     softcap=softcap, partial=True,
                                     dma_depth=dma_depth())
    return paged_attention_partial_ref(q, kv_pages, block_tables, lengths,
                                       scale=scale, window=window,
                                       softcap=softcap)


def _head_sharded(q, kv_pages, block_tables, lengths, *, scale,
                  window, softcap, mesh, axis):
    """KV heads shard on ``axis``; q's head dim is kv-major (see ``_qkv``),
    so an equal contiguous H-split keeps every query head on the shard that
    owns its KV head. Each shard runs the unmodified single-device path on
    its slice (per-head math is independent — numerics identical)."""
    def one_shard(q_, kv_, bt_, ln_):
        return _single_device(q_, kv_, bt_, ln_, scale=scale,
                              window=window, softcap=softcap)

    fn = shard_map(one_shard, mesh=mesh,
                   in_specs=(P(None, axis, None),
                             P(axis, None, None, None, None),
                             P(None, None), P(None)),
                   out_specs=P(None, axis, None))
    out = fn(q, kv_pages, block_tables, lengths)
    # re-replicate (one all-gather, no arithmetic): every op downstream of
    # attention then sees the full operand and stays bit-identical to the
    # single-device program.
    return jax.lax.with_sharding_constraint(out, NamedSharding(mesh, P()))


def _seq_sharded(q, kv_pages, block_tables, lengths, *, scale,
                 window, softcap, mesh, axis):
    """Replicated pages, block-table columns sharded: shard i owns logical
    pages [i*n/m, (i+1)*n/m) of every row and contributes the un-normalized
    flash state from the partial-softmax kernel/oracle (both masks depend
    only on ``length - k_pos``, so shard-local lengths ``len - offset``
    carry the global semantics). The flash-decode combine — ``pmax`` of the
    maxima, ``psum`` of the rescaled normalizer and value partials — is the
    only cross-shard arithmetic."""
    m = axis_size(mesh, axis)
    B, H, D = q.shape
    ps = kv_pages.shape[3]
    n = block_tables.shape[1]
    if n % m:
        # pad with page 0: the padded columns sit past every row's valid
        # length, so the mask kills them before the softmax. Pin the concat
        # result replicated — left to GSPMD auto-sharding, the padded table
        # can pick up a partial sharding whose reshard into the shard_map
        # in_spec SUMS table entries across the unmentioned mesh axes
        # (observed on 2x4 CPU meshes: page ids doubled).
        pad = m - n % m
        block_tables = jnp.concatenate(
            [block_tables, jnp.zeros((B, pad), block_tables.dtype)], axis=1)
        block_tables = jax.lax.with_sharding_constraint(
            block_tables, NamedSharding(mesh, P()))
    n_loc = block_tables.shape[1] // m

    def one_shard(q_, kvp, bt_, ln):
        i = jax.lax.axis_index(axis)
        ln_loc = ln - i * (n_loc * ps)          # shard-local valid lengths
        acc, mx, l = _partials(q_, kvp, bt_, ln_loc, scale=scale,
                               window=window, softcap=softcap)
        m_glob = jax.lax.pmax(mx, axis)         # exact: max is associative
        c = jnp.exp(mx - m_glob)
        num = jax.lax.psum(acc * c[..., None], axis)
        den = jax.lax.psum(l * c, axis)
        return (num / jnp.maximum(den, 1e-30)[..., None]).astype(q_.dtype)

    fn = shard_map(one_shard, mesh=mesh,
                   in_specs=(P(), P(), P(None, axis), P()),
                   out_specs=P())
    return fn(q, kv_pages, block_tables, lengths)


def paged_attention_auto(q, kv_pages, block_tables, lengths, *, scale,
                         window=0, softcap=0.0, mesh=None, axis="model"):
    """Mesh-aware dispatch used inside the model's paged-decode forward (see
    module docstring). ``mesh=None`` (or a 1-wide ``axis``) is the exact
    pre-mesh single-device path."""
    m = axis_size(mesh, axis)
    if m <= 1:
        return _single_device(q, kv_pages, block_tables, lengths,
                              scale=scale, window=window, softcap=softcap)
    if head_shards(kv_pages.shape[0], mesh, axis) > 1:
        return _head_sharded(q, kv_pages, block_tables, lengths,
                             scale=scale, window=window, softcap=softcap,
                             mesh=mesh, axis=axis)
    return _seq_sharded(q, kv_pages, block_tables, lengths,
                        scale=scale, window=window, softcap=softcap,
                        mesh=mesh, axis=axis)
