"""Jitted public wrapper for the paged-attention decode kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import paged_attention


@functools.partial(jax.jit, static_argnames=("scale", "window", "softcap",
                                             "interpret"))
def paged_attention_op(q, k_pages, v_pages, block_tables, lengths, *, scale,
                       window=0, softcap=0.0, interpret=False):
    return paged_attention(q, k_pages, v_pages, block_tables, lengths,
                           scale=scale, window=window, softcap=softcap,
                           interpret=interpret)
