"""Jitted public wrapper + sharded dispatch for the paged-attention decode
kernel.

``paged_attention_auto`` is the serving engine's entry point. Single device
(``mesh=None`` or a 1-wide axis) dispatches exactly as before: the Pallas TPU
kernel on TPU, the pure-jnp oracle elsewhere. On a mesh it runs under
``shard_map``:

* **head-sharded** (KV head count divides the axis): every shard holds a head
  slice of the physical page pools and runs the unmodified kernel/oracle on
  its slice — the kernel grid shrinks with the per-shard head count and no
  collective touches the softmax. The [B, H, D] output is re-replicated with
  one all-gather (pure data movement), so downstream replicated math is
  bit-identical to the single-device program.
* **sequence-sharded fallback** (heads don't divide — mirroring
  ``launch/sharding.py``'s KV cache rule): pages stay replicated and each
  shard attends over a column slice of the block tables, combining partial
  softmax state flash-decode style (global ``pmax`` of row maxima, ``psum``
  of the normalizer and of the value-weighted partials). This fallback uses
  the jnp oracle math on every backend; a Pallas partial-softmax kernel is a
  recorded follow-on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import NEG_INF, paged_attention_ref
from repro.kernels.shard_utils import axis_size, head_shards, shard_map


@functools.partial(jax.jit, static_argnames=("scale", "window", "softcap",
                                             "interpret"))
def paged_attention_op(q, k_pages, v_pages, block_tables, lengths, *, scale,
                       window=0, softcap=0.0, interpret=False):
    return paged_attention(q, k_pages, v_pages, block_tables, lengths,
                           scale=scale, window=window, softcap=softcap,
                           interpret=interpret)


def _single_device(q, k_pages, v_pages, block_tables, lengths, *, scale,
                   window, softcap):
    """Backend dispatch on one shard/device: the Pallas TPU kernel on TPU,
    the pure-jnp oracle elsewhere (CPU CI boxes). Traceable either way —
    the choice is made at trace time."""
    if jax.default_backend() == "tpu":
        return paged_attention(q, k_pages, v_pages, block_tables, lengths,
                               scale=scale, window=window, softcap=softcap)
    return paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                               scale=scale, window=window, softcap=softcap)


def _head_sharded(q, k_pages, v_pages, block_tables, lengths, *, scale,
                  window, softcap, mesh, axis):
    """KV heads shard on ``axis``; q's head dim is kv-major (see ``_qkv``),
    so an equal contiguous H-split keeps every query head on the shard that
    owns its KV head. Each shard runs the unmodified single-device path on
    its slice (per-head math is independent — numerics identical)."""
    def one_shard(q_, k_, v_, bt_, ln_):
        return _single_device(q_, k_, v_, bt_, ln_, scale=scale,
                              window=window, softcap=softcap)

    fn = shard_map(one_shard, mesh=mesh,
                   in_specs=(P(None, axis, None),
                             P(axis, None, None, None),
                             P(axis, None, None, None),
                             P(None, None), P(None)),
                   out_specs=P(None, axis, None))
    out = fn(q, k_pages, v_pages, block_tables, lengths)
    # re-replicate (one all-gather, no arithmetic): every op downstream of
    # attention then sees the full operand and stays bit-identical to the
    # single-device program.
    return jax.lax.with_sharding_constraint(out, NamedSharding(mesh, P()))


def _seq_sharded(q, k_pages, v_pages, block_tables, lengths, *, scale,
                 window, softcap, mesh, axis):
    """Replicated pages, block-table columns sharded: shard i owns logical
    pages [i*n/m, (i+1)*n/m) of every row and contributes a partial softmax
    (flash-decode semantics). The math mirrors ``paged_attention_ref`` term
    for term — only the cross-shard grouping of the sums differs."""
    m = axis_size(mesh, axis)
    B, H, D = q.shape
    ps = k_pages.shape[2]
    n = block_tables.shape[1]
    if n % m:
        # pad with page 0: the padded columns sit past every row's valid
        # length, so the mask below kills them before the softmax. Pin the
        # concat result replicated — left to GSPMD auto-sharding, the padded
        # table can pick up a partial sharding whose reshard into the
        # shard_map in_spec SUMS table entries across the unmentioned mesh
        # axes (observed on 2x4 CPU meshes: page ids doubled).
        pad = m - n % m
        block_tables = jnp.concatenate(
            [block_tables, jnp.zeros((B, pad), block_tables.dtype)], axis=1)
        block_tables = jax.lax.with_sharding_constraint(
            block_tables, NamedSharding(mesh, P()))
    n_loc = block_tables.shape[1] // m

    def one_shard(q_, kp, vp, bt_, ln):
        i = jax.lax.axis_index(axis)
        Hkv = kp.shape[0]
        G = H // Hkv
        k_seq = kp[:, bt_]                      # [Hkv, B, n_loc, ps, D]
        v_seq = vp[:, bt_]
        k_seq = k_seq.transpose(1, 0, 2, 3, 4).reshape(B, Hkv, n_loc * ps, D)
        v_seq = v_seq.transpose(1, 0, 2, 3, 4).reshape(B, Hkv, n_loc * ps, D)
        qg = q_.reshape(B, Hkv, G, D)
        s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_seq,
                       preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = i * (n_loc * ps) + jnp.arange(n_loc * ps)   # global positions
        mask = k_pos[None, None, None, :] < ln[:, None, None, None]
        if window > 0:
            mask &= k_pos[None, None, None, :] >= (ln - window)[:, None, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_loc = jnp.max(s, axis=-1, keepdims=True)
        m_glob = jax.lax.pmax(m_loc, axis)      # exact: max is associative
        e = jnp.exp(s - m_glob)
        den = jax.lax.psum(jnp.sum(e, axis=-1, keepdims=True), axis)
        p = (e / den).astype(v_seq.dtype)
        out = jnp.einsum("bhgk,bhkd->bhgd", p, v_seq,
                         preferred_element_type=jnp.float32)
        out = jax.lax.psum(out, axis)
        return out.reshape(B, H, D).astype(q_.dtype)

    fn = shard_map(one_shard, mesh=mesh,
                   in_specs=(P(), P(), P(), P(None, axis), P()),
                   out_specs=P())
    return fn(q, k_pages, v_pages, block_tables, lengths)


def paged_attention_auto(q, k_pages, v_pages, block_tables, lengths, *, scale,
                         window=0, softcap=0.0, mesh=None, axis="model"):
    """Mesh-aware dispatch used inside the model's paged-decode forward (see
    module docstring). ``mesh=None`` (or a 1-wide ``axis``) is the exact
    pre-mesh single-device path."""
    m = axis_size(mesh, axis)
    if m <= 1:
        return _single_device(q, k_pages, v_pages, block_tables, lengths,
                              scale=scale, window=window, softcap=softcap)
    if head_shards(k_pages.shape[0], mesh, axis) > 1:
        return _head_sharded(q, k_pages, v_pages, block_tables, lengths,
                             scale=scale, window=window, softcap=softcap,
                             mesh=mesh, axis=axis)
    return _seq_sharded(q, k_pages, v_pages, block_tables, lengths,
                        scale=scale, window=window, softcap=softcap,
                        mesh=mesh, axis=axis)
