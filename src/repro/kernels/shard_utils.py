"""shard_map compatibility shim + mesh helpers for sharded kernel dispatch.

The serving executor runs the fused paged steps under ``jax.jit`` on a mesh;
inside those steps the attention ops are the only mesh-aware computation
(everything else is replicated math on replicated operands). The ops modules
use :func:`shard_map` from here so one jax-version shim covers MoE expert
parallelism and the paged-attention shards alike.
"""
from __future__ import annotations

try:  # jax >= 0.5 exports shard_map at top level (``check_vma`` kwarg)
    from jax import shard_map as _shard_map_impl
    _SHARD_MAP_CHECK_KW = "check_vma"
except ImportError:  # older jax (0.4.x): experimental module, ``check_rep`` kwarg
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _SHARD_MAP_CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    kw = {_SHARD_MAP_CHECK_KW: check_vma}
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)


def axis_size(mesh, axis: str) -> int:
    """Size of ``axis`` on ``mesh``; 1 when there is no mesh (single-device
    dispatch) or the mesh does not carry the axis."""
    if mesh is None or axis not in mesh.axis_names:
        return 1
    return int(mesh.shape[axis])


def head_shards(num_kv_heads: int, mesh, axis: str) -> int:
    """The ONE partition rule for paged KV: how many ways the KV heads (and
    with them the page pools) split on ``axis`` — the axis size when it
    divides the head count, else 1 (replicated pools + sequence-sharded
    attention fallback). Both ops dispatchers, ``paged_cache_specs`` and
    ``EngineCore.kv_shards`` consult this so cache placement, kernel
    dispatch and reporting can never disagree."""
    m = axis_size(mesh, axis)
    return m if m > 1 and num_kv_heads % m == 0 else 1
