"""Selective-state-space (Mamba-1) scan Pallas TPU kernel.

The CUDA selective-scan kernel streams the recurrence through shared memory
with warp-level parallel prefix tricks; the TPU adaptation instead:

* parallelizes over the *channel* dimension (grid axis ``d_tiles`` — channels
  are fully independent in Mamba-1) and keeps the time recurrence sequential
  inside the kernel, where the state ``h [d_tile, n]`` lives in VMEM scratch
  (VPU elementwise work; there is no matmul to win on the MXU here),
* chunks the sequence on the innermost grid axis so each step only holds a
  ``[chunk, d_tile]`` activation tile in VMEM, with the state carried across
  chunk steps in scratch — HBM traffic is exactly one read of (x, dt, B, C)
  and one write of y.

Layout note: time-major ``[S, d]`` blocks so the lane dimension (128-wide) is
the channel axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref,   # blocks
            y_ref,                                       # out [1, c, dt]
            h_ref,                                       # scratch [dt, n] f32
            *, chunk: int, d_state: int):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...]                                # [d_tile, n] f32 (negative)
    Dp = d_ref[...]                               # [d_tile]

    def step(t, h):
        x_t = x_ref[0, t].astype(jnp.float32)     # [d_tile]
        dt_t = dt_ref[0, t].astype(jnp.float32)   # [d_tile]
        b_t = b_ref[0, t].astype(jnp.float32)     # [n]
        c_t = c_ref[0, t].astype(jnp.float32)     # [n]
        da = jnp.exp(dt_t[:, None] * A)           # [d_tile, n]
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1) + Dp * x_t
        y_ref[0, t] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h


def mamba_scan(
    x: jnp.ndarray,    # [B, S, d_inner]
    dt: jnp.ndarray,   # [B, S, d_inner]  (already softplus'd)
    Bc: jnp.ndarray,   # [B, S, n]
    Cc: jnp.ndarray,   # [B, S, n]
    A: jnp.ndarray,    # [d_inner, n] f32 (negative)
    D: jnp.ndarray,    # [d_inner] f32
    *,
    chunk: int = 256,
    d_tile: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, d_inner = x.shape
    n = A.shape[1]
    chunk = min(chunk, S)
    d_tile = min(d_tile, d_inner)
    assert S % chunk == 0 and d_inner % d_tile == 0
    nc, nd = S // chunk, d_inner // d_tile

    kernel = functools.partial(_kernel, chunk=chunk, d_state=n)
    out = pl.pallas_call(
        kernel,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d_tile), lambda b, di, cj: (b, cj, di)),
            pl.BlockSpec((1, chunk, d_tile), lambda b, di, cj: (b, cj, di)),
            pl.BlockSpec((1, chunk, n), lambda b, di, cj: (b, cj, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, di, cj: (b, cj, 0)),
            pl.BlockSpec((d_tile, n), lambda b, di, cj: (di, 0)),
            pl.BlockSpec((d_tile,), lambda b, di, cj: (di,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_tile), lambda b, di, cj: (b, cj, di)),
        out_shape=jax.ShapeDtypeStruct((B, S, d_inner), x.dtype),
        scratch_shapes=[pltpu.VMEM((d_tile, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, Bc, Cc, A.astype(jnp.float32), D.astype(jnp.float32))
    return out
