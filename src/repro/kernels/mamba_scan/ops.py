"""Jitted public wrapper for the Mamba scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mamba_scan.kernel import mamba_scan


@functools.partial(jax.jit, static_argnames=("chunk", "d_tile", "interpret"))
def mamba_scan_op(x, dt, Bc, Cc, A, D, *, chunk=256, d_tile=256,
                  interpret=False):
    return mamba_scan(x, dt, Bc, Cc, A, D, chunk=chunk, d_tile=d_tile,
                      interpret=interpret)
