"""Pure-jnp oracle for the Mamba selective scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(x, dt, Bc, Cc, A, D):
    """x/dt: [B, S, d]; Bc/Cc: [B, S, n]; A: [d, n]; D: [d]."""
    B, S, d = x.shape
    n = A.shape[1]
    Af = A.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * Af[None])
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (x.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bc.transpose(1, 0, 2).astype(jnp.float32),
          Cc.transpose(1, 0, 2).astype(jnp.float32))
    h0 = jnp.zeros((B, d, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + D.astype(jnp.float32)[None, None, :] * x.astype(jnp.float32)
    return y.astype(x.dtype)
