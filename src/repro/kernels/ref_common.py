"""Shared jnp oracle math for the paged attention kernels.

``paged_attention/ref.py`` (decode) and ``paged_prefill_attention/ref.py``
(ragged chunked prefill) used to each carry their own copy of the same
gather-pages + masked-softmax reference; both are now thin wrappers over this
module, so the fused-layout refs and the partial-softmax oracles are written
exactly once. Every helper reproduces the original refs' operations *in the
same order* — the slot-vs-paged engine equivalence suite and the bit-identical
greedy-token guarantees ride on the oracles staying bitwise stable.

Layouts:

* **split**: separate ``k_pages``/``v_pages`` pools, each ``[Hkv, P, ps, D]``
  (the pre-fusion layout, kept for the layout A/B benchmarks).
* **fused head-interleaved**: one pool ``[Hkv, P, 2, ps, D]`` with K at
  interleave index 0 and V at index 1 (tpu_commons-v3 style) — half the pool
  objects, one DMA per (head, page) instead of two.

Partials: the ``*_partials`` variants return the un-normalized flash-softmax
state ``(acc, m, l)`` — ``acc = sum(exp(s - m) @ v)``, ``m = row max``,
``l = sum(exp(s - m))`` — which the sequence-sharded mesh fallback combines
across shards flash-decode style (``pmax`` of m, ``psum`` of rescaled acc/l).
``finalize_partials`` reproduces the kernels' final division bit-for-bit.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30

K_IDX, V_IDX = 0, 1   # interleave positions inside a fused page


def split_fused(kv_pages: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Views of the K and V planes of a fused pool [Hkv, P, 2, ps, D]."""
    return kv_pages[:, :, K_IDX], kv_pages[:, :, V_IDX]


# ---------------------------------------------------------------------------
# gathers
# ---------------------------------------------------------------------------
def gather_seq(pages: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    """[Hkv, P, ps, D] + [B, n] -> each row's logical view [B, Hkv, n*ps, D]
    (the decode oracle's operand layout)."""
    g = pages[:, block_tables]                  # [Hkv, B, n, ps, D]
    Hkv, B, n, ps, D = g.shape
    return g.transpose(1, 0, 2, 3, 4).reshape(B, Hkv, n * ps, D)


def gather_rows(pages: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    """[Hkv, P, ps, D] + [R, n] -> [R, n*ps, Hkv, D] (the prefill oracle's
    operand layout — identical to ``models.attention.gather_pages``)."""
    g = pages[:, block_tables]                  # [Hkv, R, n, ps, D]
    Hkv, R, n, ps, D = g.shape
    return g.transpose(1, 2, 3, 0, 4).reshape(R, n * ps, Hkv, D)


# ---------------------------------------------------------------------------
# decode (one query token per sequence)
# ---------------------------------------------------------------------------
def decode_scores(q: jnp.ndarray, k_seq: jnp.ndarray, *, scale: float,
                  softcap: float) -> jnp.ndarray:
    """[B, H, D] x [B, Hkv, Sk, D] -> masked-input scores [B, Hkv, G, Sk]."""
    B, H, D = q.shape
    Hkv = k_seq.shape[1]
    qg = q.reshape(B, Hkv, H // Hkv, D)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_seq,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    return s


def decode_mask(s: jnp.ndarray, lengths: jnp.ndarray, *, window: int,
                k_pos: jnp.ndarray) -> jnp.ndarray:
    """Valid-length + sliding-window mask at (possibly shard-local) key
    positions ``k_pos`` [Sk]; masked entries become NEG_INF."""
    mask = k_pos[None, None, None, :] < lengths[:, None, None, None]
    if window > 0:
        mask &= k_pos[None, None, None, :] >= (lengths - window)[:, None, None, None]
    return jnp.where(mask, s, NEG_INF)


def decode_softmax_v(s: jnp.ndarray, v_seq: jnp.ndarray,
                     out_dtype) -> jnp.ndarray:
    """Full (normalized) softmax @ V: [B, Hkv, G, Sk] -> [B, H, D]."""
    B, Hkv, G, _ = s.shape
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p.astype(v_seq.dtype), v_seq,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hkv * G, v_seq.shape[-1]).astype(out_dtype)


def decode_partials(s: jnp.ndarray, v_seq: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Un-normalized flash state from masked scores: acc [B, H, D] f32,
    m [B, H] f32, l [B, H] f32. ``finalize_partials`` (or the cross-shard
    combine) turns this into the attention output."""
    B, Hkv, G, _ = s.shape
    m = jnp.max(s, axis=-1)                               # [B, Hkv, G]
    e = jnp.exp(s - m[..., None])
    l = jnp.sum(e, axis=-1)
    acc = jnp.einsum("bhgk,bhkd->bhgd", e.astype(v_seq.dtype), v_seq,
                     preferred_element_type=jnp.float32)
    D = v_seq.shape[-1]
    return (acc.reshape(B, Hkv * G, D), m.reshape(B, Hkv * G),
            l.reshape(B, Hkv * G))


def finalize_partials(acc: jnp.ndarray, l: jnp.ndarray,
                      out_dtype) -> jnp.ndarray:
    """The kernels' finalize step, bit-for-bit: acc / max(l, 1e-30)."""
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(out_dtype)


def combine_partials(parts, out_dtype):
    """Merge flash partials from disjoint key ranges: ``parts`` is a sequence
    of (acc, m, l) triples. Pure-jnp mirror of the mesh fallback's
    ``pmax``/``psum`` combine (used by tests; the sharded path inlines the
    same formula with lax collectives)."""
    m_glob = parts[0][1]
    for _, m, _ in parts[1:]:
        m_glob = jnp.maximum(m_glob, m)
    acc = jnp.zeros_like(parts[0][0])
    l = jnp.zeros_like(parts[0][2])
    for a, m, s in parts:
        c = jnp.exp(m - m_glob)
        acc = acc + a * c[..., None]
        l = l + s * c
    return finalize_partials(acc, l, out_dtype)


# ---------------------------------------------------------------------------
# ragged chunked prefill (rows of Sq queries at per-row cache offsets)
# ---------------------------------------------------------------------------
def prefill_scores(q: jnp.ndarray, k_all: jnp.ndarray, *, scale: float,
                   softcap: float) -> jnp.ndarray:
    """[R, Sq, Hkv, G, D] x [R, Sk, Hkv, D] -> scores [R, Hkv, G, Sq, Sk]."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k_all,
                   preferred_element_type=jnp.float32) * scale
    if softcap and softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    return s


def prefill_mask(s: jnp.ndarray, row_pos: jnp.ndarray, lengths: jnp.ndarray,
                 *, window: int, k_pos: jnp.ndarray, Sq: int) -> jnp.ndarray:
    """Causal-at-offset + sliding-window + valid-length mask at (possibly
    shard-local) key positions ``k_pos`` [Sk]."""
    q_pos = jnp.asarray(row_pos).reshape(-1, 1) + jnp.arange(Sq)[None, :]
    mask = k_pos[None, None, :] <= q_pos[:, :, None]          # [R, Sq, Sk]
    if window and window > 0:
        mask = mask & (q_pos[:, :, None] - k_pos[None, None, :] < window)
    mask = mask & (k_pos[None, None, :]
                   < jnp.asarray(lengths).reshape(-1, 1, 1))
    mask = mask[:, None, None]                                # [R,1,1,Sq,Sk]
    return jnp.where(mask, s, NEG_INF)


def prefill_softmax_v(s: jnp.ndarray, v_all: jnp.ndarray) -> jnp.ndarray:
    """Full softmax @ V: -> [R, Sq, Hkv, G, D] (the refs' return layout)."""
    p = jax.nn.softmax(s, axis=-1).astype(v_all.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v_all)


def prefill_partials(s: jnp.ndarray, v_all: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Un-normalized flash state for the prefill shape: acc
    [R, Sq, Hkv, G, D] f32, m/l [R, Sq, Hkv, G] f32 (query-major so the
    caller's combine broadcasts cleanly)."""
    m = jnp.max(s, axis=-1)                                   # [R, Hkv, G, Sq]
    e = jnp.exp(s - m[..., None])
    l = jnp.sum(e, axis=-1)
    acc = jnp.einsum("bhgqk,bkhd->bqhgd", e.astype(v_all.dtype), v_all,
                     preferred_element_type=jnp.float32)
    return acc, m.transpose(0, 3, 1, 2), l.transpose(0, 3, 1, 2)
