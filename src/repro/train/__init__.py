"""Training substrate: optimizer, train-step factory, data, checkpointing."""
