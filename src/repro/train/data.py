"""Synthetic packed-token data pipeline.

Deterministic PRNG "documents" with a Zipf-like unigram distribution and a
weak Markov structure (so the loss actually decreases during the example
runs), packed into fixed ``[B, S]`` batches with EOS separators. Sharding:
each data-parallel rank slices its batch rows by ``(rank, world)`` — the
global batch is identical regardless of world size, so elastic re-runs are
bitwise reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 0
    mean_doc_len: int = 512


class PackedSyntheticData:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1)
        self._unigram = (1.0 / ranks ** 1.1)
        self._unigram /= self._unigram.sum()
        # weak bigram structure: token t prefers a band around f(t)
        self._shift = rng.integers(1, max(v - 1, 2))

    def _doc(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        n = max(8, int(rng.exponential(cfg.mean_doc_len)))
        first = rng.choice(cfg.vocab_size, p=self._unigram)
        toks = [first]
        for _ in range(n - 1):
            if rng.random() < 0.5:  # markov step: predictable half the time
                toks.append((toks[-1] * 7 + self._shift) % self.cfg.vocab_size)
            else:
                toks.append(rng.choice(cfg.vocab_size, p=self._unigram))
        return np.asarray(toks, np.int32)

    def batch(self, step: int, rank: int = 0, world: int = 1) -> np.ndarray:
        """Deterministic [global_batch // world, seq_len] batch slice."""
        cfg = self.cfg
        assert cfg.global_batch % world == 0
        rows_per = cfg.global_batch // world
        out = np.empty((rows_per, cfg.seq_len), np.int32)
        for i in range(rows_per):
            row_global = rank * rows_per + i
            rng = np.random.default_rng(
                (cfg.seed, step, row_global))
            buf = []
            while len(buf) < cfg.seq_len:
                buf.extend(self._doc(rng).tolist())
                buf.append(cfg.eos_id)
            out[i] = np.asarray(buf[: cfg.seq_len], np.int32)
        return out

    def batches(self, steps: int, rank: int = 0, world: int = 1) -> Iterator[np.ndarray]:
        for s in range(steps):
            yield self.batch(s, rank, world)
