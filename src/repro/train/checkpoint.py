"""Sharded checkpointing: per-leaf .npy shards + a msgpack manifest.

Layout (one directory per step)::

    ckpt_dir/step_000123/
        manifest.msgpack        # treedef paths, shapes, dtypes, step, mesh
        leaf_00000.npy ...      # one file per pytree leaf

Saves are atomic (write to ``.tmp`` then rename) and optionally asynchronous
(background thread — the training loop never blocks on disk). Restore is
mesh-agnostic: arrays are loaded on host and re-placed with whatever sharding
the (possibly different-size) new mesh dictates — this is the elastic-restart
path (``repro.runtime.elastic``).
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _leaf_paths(tree) -> list:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(path))
    return paths


_NP_UNSUPPORTED = ("bfloat16", "float8_e4m3fn", "float8_e5m2")


def _to_storable(x: np.ndarray):
    """numpy .npy cannot round-trip ml_dtypes types; store a byte view."""
    if str(x.dtype) in _NP_UNSUPPORTED:
        return x.view(np.uint8 if x.dtype.itemsize == 1 else np.uint16), str(x.dtype)
    return x, str(x.dtype)


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None,
         async_save: bool = False) -> Optional[threading.Thread]:
    """Serialize a pytree. Returns the writer thread when async."""
    leaves = jax.tree.leaves(tree)
    host_leaves = [np.asarray(x) for x in leaves]
    paths = _leaf_paths(tree)

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        storable = [_to_storable(x) for x in host_leaves]
        manifest = {
            "step": step,
            "paths": paths,
            "shapes": [list(x.shape) for x in host_leaves],
            "dtypes": [d for _, d in storable],
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        for i, (x, _) in enumerate(storable):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), x)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Rebuild the pytree of ``like``'s structure; optionally re-shard each
    leaf (elastic restart onto a different mesh)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    leaves_like, treedef = jax.tree.flatten(like)
    assert len(leaves_like) == len(manifest["paths"]), (
        f"checkpoint has {len(manifest['paths'])} leaves, "
        f"expected {len(leaves_like)}")
    out = []
    shard_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
                    if shardings is not None else [None] * len(leaves_like))
    import ml_dtypes
    for i, (ref, sh) in enumerate(zip(leaves_like, shard_leaves)):
        x = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        stored_dtype = manifest["dtypes"][i]
        if stored_dtype in _NP_UNSUPPORTED:
            x = x.view(ml_dtypes.bfloat16 if stored_dtype == "bfloat16"
                       else np.dtype(getattr(ml_dtypes, stored_dtype)))
        arr = jnp.asarray(x, dtype=ref.dtype)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        out.append(arr)
    return treedef.unflatten(out)


def manifest_of(ckpt_dir: str, step: int) -> dict:
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read())
