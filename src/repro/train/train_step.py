"""Train-step factory: loss + grads + AdamW update (+ optional gradient
compression for the cross-pod hop), with configurable remat policy and
gradient accumulation."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import RunCtx, loss_fn
from repro.runtime import compression
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    grad_accum: int = 1
    compress_grads: bool = False


def init_train_state(cfg: ModelConfig, params, train_cfg: TrainConfig):
    state = {"opt": adamw_init(params)}
    if train_cfg.compress_grads:
        state["ef"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def make_train_step(cfg: ModelConfig, rctx: RunCtx, train_cfg: TrainConfig):
    """Returns train_step(params, state, batch) -> (params, state, metrics).

    ``batch["tokens"]``: [B, S] (plus optional modality-frontend entries).
    With grad_accum > 1, the batch is split along B and accumulated via scan
    (bounds activation memory; grads stream into the fp32 accumulator).
    """

    def loss_wrapped(p, micro):
        return loss_fn(cfg, p, micro, rctx)

    def compute_grads(params, batch):
        if train_cfg.grad_accum == 1:
            return jax.value_and_grad(loss_wrapped)(params, batch)
        B = batch["tokens"].shape[0]
        assert B % train_cfg.grad_accum == 0
        micro_b = B // train_cfg.grad_accum

        def micro_slice(i, arr):
            return jax.lax.dynamic_slice_in_dim(arr, i * micro_b, micro_b, 0)

        def body(carry, i):
            loss_acc, grad_acc = carry
            micro = {k: micro_slice(i, v) for k, v in batch.items()}
            loss, grads = jax.value_and_grad(loss_wrapped)(params, micro)
            grad_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
            return (loss_acc + loss, grad_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(
            body, (jnp.zeros(()), zeros), jnp.arange(train_cfg.grad_accum))
        inv = 1.0 / train_cfg.grad_accum
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(params, state, batch):
        loss, grads = compute_grads(params, batch)
        if train_cfg.compress_grads:
            grads, new_ef = compression.compress_tree(grads, state["ef"])
        new_params, new_opt, metrics = adamw_update(
            train_cfg.optimizer, params, grads, state["opt"])
        new_state = {"opt": new_opt}
        if train_cfg.compress_grads:
            new_state["ef"] = new_ef
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    return train_step
