"""AdamW in pure JAX with fp32 master weights for bf16 params.

Sharding posture (ZeRO-1/2 style): the optimizer state pytree mirrors the
param pytree, so the launcher assigns it PartitionSpecs that shard the moments
(and master copy) over the (pod, data) axes in addition to the params' model
sharding — see ``repro.launch.sharding.opt_state_specs``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params) -> dict:
    def zeros_like_f32(p):
        return jnp.zeros(p.shape, jnp.float32)
    master = jax.tree.map(
        lambda p: p.astype(jnp.float32) if p.dtype != jnp.float32 else None, params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like_f32, params),
        "v": jax.tree.map(zeros_like_f32, params),
        "master": master,
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    bc1 = 1.0 - cfg.beta1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * clip
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        base = master if master is not None else p.astype(jnp.float32)
        new_master = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                  + cfg.weight_decay * base)
        new_p = new_master.astype(p.dtype)
        return new_p, m, v, (new_master if master is not None else None)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_ma = treedef.flatten_up_to(state["master"])
    out = [upd(p, g, m, v, ma)
           for p, g, m, v, ma in zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "step": step,
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "master": treedef.unflatten([o[3] for o in out]),
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
