"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

from repro.configs.base import SHAPES, INFERENCE_SHAPES, ModelConfig, ShapeSpec

_ARCH_MODULES = {
    "xlstm-125m": "xlstm_125m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llama3.2-3b": "llama3_2_3b",
    "gemma2-27b": "gemma2_27b",
    "qwen3-1.7b": "qwen3_1_7b",
    "gemma2-2b": "gemma2_2b",
    "internvl2-26b": "internvl2_26b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}

ARCHS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    import importlib

    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def all_cells() -> list[tuple[str, ShapeSpec]]:
    """Every assigned (arch x shape) cell, including ones later marked skip."""
    return [(a, s) for a in ARCHS for s in SHAPES.values()]


def cell_skip_reason(arch: str, shape: ShapeSpec) -> str | None:
    """Assignment rules: long_500k runs for SSM/hybrid/linear-attention archs
    and is skipped for pure full-attention archs (see DESIGN.md §5)."""
    cfg = get_config(arch)
    if shape.name == "long_500k" and not (
            cfg.is_subquadratic() or cfg.family in ("ssm", "hybrid")):
        return "pure full-attention arch: long_500k requires sub-quadratic attention (see DESIGN.md §5)"
    return None
