"""qwen3-1.7b — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
"""
from repro.configs.base import ATTN, DENSE, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    layer_pattern=(ATTN,),
    ffn_pattern=(DENSE,),
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
)
