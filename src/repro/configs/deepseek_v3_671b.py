"""deepseek-v3-671b — MLA, 1 shared+256 routed top-8, MTP [arXiv:2412.19437; hf].

61L d_model=7168 128H (kv=128: MLA latent shared, per-head keys expanded from
the 512-dim compressed cache) d_ff=2048 (per routed expert) vocab=129280,
MoE 256e top-8 + 1 shared expert; first 3 layers dense (d_ff 18432);
aux-loss-free bias routing; 1 MTP module.
"""
from repro.configs.base import DENSE, MLA, MOE, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=18432,  # dense-prefix layers
    vocab_size=129280,
    layer_pattern=(MLA,),
    ffn_pattern=(MOE,),
    first_k_dense=3,
    num_experts=256,
    num_experts_per_tok=8,
    moe_d_ff=2048,
    shared_expert_d_ff=2048,
    router_aux_free=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    mtp_depth=1,
)
