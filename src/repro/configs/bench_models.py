"""The paper's own evaluation models (§5: Llama3-8B, Qwen2.5-7B).

These are *benchmark* configs (not part of the assigned 10-arch grid): the
goodput/violation experiments replicate the paper's setup with these models'
cost profiles on the serving simulator.
"""
from repro.configs.base import ATTN, DENSE, ModelConfig

LLAMA3_8B = ModelConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    layer_pattern=(ATTN,),
    ffn_pattern=(DENSE,),
    rope_theta=500_000.0,
)

QWEN25_7B = ModelConfig(
    name="qwen2.5-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    layer_pattern=(ATTN,),
    ffn_pattern=(DENSE,),
    rope_theta=1_000_000.0,
)

BENCH_MODELS = {"llama3-8b": LLAMA3_8B, "qwen2.5-7b": QWEN25_7B}
