"""internvl2-26b — InternViT + InternLM2 [arXiv:2404.16821; hf].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553. The InternViT
frontend is a STUB per the assignment: ``input_specs()`` provides precomputed
patch embeddings (num_patch_tokens per sample) that are concatenated ahead of
the text embeddings; the transformer backbone here is the InternLM2-20B-style
decoder (GQA, SwiGLU).
"""
from repro.configs.base import ATTN, DENSE, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    layer_pattern=(ATTN,),
    ffn_pattern=(DENSE,),
    rope_theta=1_000_000.0,
    num_patch_tokens=256,
)
