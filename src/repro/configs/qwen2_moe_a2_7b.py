"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, MoE 60e top-4.
The 4 shared experts are fused into a single shared FFN of width 4*1408=5632
(mathematically identical to 4 parallel always-on experts summed).
"""
from repro.configs.base import ATTN, MOE, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    layer_pattern=(ATTN,),
    ffn_pattern=(MOE,),
    num_experts=60,
    num_experts_per_tok=4,
    moe_d_ff=1408,
    shared_expert_d_ff=5632,
)
