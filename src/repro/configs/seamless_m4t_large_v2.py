"""seamless-m4t-large-v2 — enc-dec, multimodal [arXiv:2308.11596; hf].

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206. Encoder-decoder:
24 encoder + 24 decoder layers. The speech frontend is a STUB per the
assignment — ``input_specs()`` provides precomputed frame embeddings for the
encoder. For the LM shape grid, a cell's seq_len S is split S/2 encoder
frames + S/2 decoder tokens so total token work matches the other archs
(documented in DESIGN.md §5).
"""
from repro.configs.base import ATTN, DENSE, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    layer_pattern=(ATTN,),
    ffn_pattern=(DENSE,),
    enc_dec=True,
    num_encoder_layers=24,
    audio_frames_ratio=2,
    activation="gelu",
)
