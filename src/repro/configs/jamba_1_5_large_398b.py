"""jamba-1.5-large-398b — Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
One attention layer per 8 (attn at slot 3 of each period, per the Jamba
paper's l=8, a=1 with the attention layer mid-block); MoE every other layer
(e=2 in Jamba notation). Mamba layers have O(1) state and the single
attention layer per period uses a cache whose per-step decode cost is linear,
but for the long_500k rule we classify by the presence of full attention:
Jamba is `hybrid` and the assignment explicitly lists hybrid as eligible.
"""
from repro.configs.base import ATTN, DENSE, MAMBA, MOE, ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    layer_pattern=(MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA, MAMBA),
    ffn_pattern=(DENSE, MOE),
    num_experts=16,
    num_experts_per_tok=2,
    moe_d_ff=24576,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    use_rope=False,  # Jamba: no positional embeddings (Mamba layers carry position)
)
