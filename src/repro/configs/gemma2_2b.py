"""gemma2-2b — local+global alternating, logit softcap [arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
"""
from repro.configs.base import ATTN, DENSE, LOCAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    layer_pattern=(LOCAL_ATTN, ATTN),
    ffn_pattern=(DENSE,),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    activation="gelu",
    post_norm=True,
    embed_scale=True,
    tie_embeddings=True,
)
