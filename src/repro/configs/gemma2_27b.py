"""gemma2-27b — local+global alternating, logit softcap [arXiv:2408.00118; hf].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
Sliding window 4096 on local layers; attn softcap 50, final softcap 30.
"""
from repro.configs.base import ATTN, DENSE, LOCAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    layer_pattern=(LOCAL_ATTN, ATTN),
    ffn_pattern=(DENSE,),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    # gemma2-27b scales queries by 1/sqrt(d_model/num_heads)=1/12, not head_dim.
    attn_scale=1.0 / 12.0,
    activation="gelu",
    post_norm=True,
    embed_scale=True,
    tie_embeddings=True,
)
