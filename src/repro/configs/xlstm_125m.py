"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304. xLSTM blocks carry their own
up/down projections, so there is no separate FFN (d_ff=0). We alternate
mLSTM/sLSTM 1:1 (the paper's xLSTM[a:b] notation; 1:1 exercises both cells).
Fully recurrent -> O(1) state -> long_500k applies.
"""
from repro.configs.base import MLSTM, NONE, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    layer_pattern=(MLSTM, SLSTM),
    ffn_pattern=(NONE,),
    tie_embeddings=True,
)
