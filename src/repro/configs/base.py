"""Model/architecture configuration.

Every assigned architecture is expressed as a ``ModelConfig``. The transformer
stack is described by a repeating ``layer_pattern`` (sequence-mixer kind per
layer slot) and ``ffn_pattern`` (channel-mixer kind per layer slot); the stack
is ``lax.scan``-ned over repetitions of the pattern period so the lowered HLO
stays compact even for 72-layer models.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence, Tuple

import jax.numpy as jnp

# Sequence-mixer kinds.
ATTN = "attn"          # full (causal) GQA attention
LOCAL_ATTN = "local"   # sliding-window GQA attention
MLA = "mla"            # DeepSeek multi-head latent attention
MAMBA = "mamba"        # Mamba-1 selective SSM
MLSTM = "mlstm"        # xLSTM matrix-memory LSTM
SLSTM = "slstm"        # xLSTM scalar-memory LSTM

# Channel-mixer kinds.
DENSE = "dense"        # gated-GLU MLP
MOE = "moe"            # routed (+ optional shared) experts
NONE = "none"          # block has no separate FFN (xLSTM blocks self-contain)

INFERENCE_SHAPES = ("prefill_32k", "decode_32k", "long_500k")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned (input shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # Repeating structural patterns (period divides num_layers unless a dense
    # prefix is configured via ``first_k_dense``).
    layer_pattern: Tuple[str, ...] = (ATTN,)
    ffn_pattern: Tuple[str, ...] = (DENSE,)
    first_k_dense: int = 0  # leading layers forced to (attn, dense) (DeepSeek)

    # Attention details.
    rope_theta: float = 10_000.0
    sliding_window: int = 4_096
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    qk_norm: bool = False
    attn_scale: float = 0.0  # 0 -> 1/sqrt(head_dim)
    use_rope: bool = True
    post_norm: bool = False     # gemma2-style post-sublayer norms
    embed_scale: bool = False   # gemma-style sqrt(d_model) embedding scaling

    # MoE.
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    shared_expert_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_free: bool = False  # DeepSeek-v3 aux-loss-free bias routing

    # MLA (DeepSeek-v3).
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # Mamba.
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # Multi-token prediction (DeepSeek-v3).
    mtp_depth: int = 0

    # Encoder-decoder (seamless-m4t).
    enc_dec: bool = False
    num_encoder_layers: int = 0

    # Modality frontend stub sizes.
    num_patch_tokens: int = 0   # vlm: image patch embeddings per sample
    audio_frames_ratio: int = 0  # audio: enc frames = seq_len // ratio (>0 => enc-dec split)

    # Numerics.
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    activation: str = "silu"

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def period(self) -> int:
        return int(math.lcm(len(self.layer_pattern), len(self.ffn_pattern)))

    def layer_kinds(self) -> list[Tuple[str, str]]:
        """(mixer, ffn) kind for every layer index (after the dense prefix)."""
        p = self.period
        out = []
        n = self.num_layers - self.first_k_dense
        for i in range(n):
            out.append(
                (
                    self.layer_pattern[i % len(self.layer_pattern)],
                    self.ffn_pattern[i % len(self.ffn_pattern)],
                )
            )
        return out

    @property
    def num_pattern_reps(self) -> int:
        n = self.num_layers - self.first_k_dense
        if n % self.period:
            raise ValueError(
                f"{self.name}: {n} scanned layers not divisible by period {self.period}"
            )
        return n // self.period

    def uses_kv_cache(self) -> bool:
        return any(k in (ATTN, LOCAL_ATTN, MLA) for k in self.layer_pattern) or self.first_k_dense > 0

    def is_subquadratic(self) -> bool:
        """True when every sequence mixer keeps O(1)/windowed state (long_500k rule)."""
        quad = {ATTN, MLA}
        return not any(k in quad for k in self.layer_pattern) and self.first_k_dense == 0

    # Parameter count (for 6ND model-flops accounting).
    def param_count(self, active_only: bool = False) -> int:
        d, h = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        kinds = [(ATTN, DENSE)] * self.first_k_dense_pairs() + self.layer_kinds()
        for mixer, ffn in kinds:
            if mixer in (ATTN, LOCAL_ATTN):
                total += d * (self.num_heads * h) + d * (2 * self.num_kv_heads * h)
                total += (self.num_heads * h) * d
            elif mixer == MLA:
                total += d * self.q_lora_rank + self.q_lora_rank * self.num_heads * (
                    self.qk_nope_head_dim + self.qk_rope_head_dim
                )
                total += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                total += self.kv_lora_rank * self.num_heads * (
                    self.qk_nope_head_dim + self.v_head_dim
                )
                total += self.num_heads * self.v_head_dim * d
            elif mixer == MAMBA:
                di = self.mamba_expand * d
                total += d * 2 * di + di * self.mamba_d_conv
                total += di * (self.mamba_d_state * 2 + di // 16) + di * d
            elif mixer in (MLSTM, SLSTM):
                di = 2 * d
                total += d * 4 * di + di * d  # qkv/gates up + down
            if ffn == DENSE:
                total += 3 * d * self.d_ff
            elif ffn == MOE:
                e = self.num_experts_per_tok if active_only else self.num_experts
                total += 3 * d * self.moe_d_ff * e
                total += 3 * d * self.shared_expert_d_ff
                total += d * self.num_experts  # router
        if self.enc_dec:
            # decoder cross-attention per decoder layer
            total += self.num_layers * (
                d * (self.num_heads + 2 * self.num_kv_heads) * h + self.num_heads * h * d
            )
        return total

    def first_k_dense_pairs(self) -> int:
        return self.first_k_dense

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        p = self.period
        changes = dict(
            num_layers=self.first_k_dense + p * (2 if self.first_k_dense == 0 else 1),
            d_model=64,
            num_heads=4,
            num_kv_heads=2,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            dtype=jnp.float32,
            param_dtype=jnp.float32,
        )
        if self.num_experts:
            changes.update(
                num_experts=max(4, self.num_experts_per_tok + 1),
                moe_d_ff=96,
                shared_expert_d_ff=96 if self.shared_expert_d_ff else 0,
                num_experts_per_tok=min(self.num_experts_per_tok, 2),
                capacity_factor=4.0,
            )
        if self.q_lora_rank:
            changes.update(
                q_lora_rank=32,
                kv_lora_rank=32,
                qk_nope_head_dim=16,
                qk_rope_head_dim=8,
                v_head_dim=16,
            )
        if self.enc_dec:
            changes.update(num_encoder_layers=2, num_layers=2)
        if self.num_patch_tokens:
            changes.update(num_patch_tokens=16)
        return dataclasses.replace(self, **changes)
