"""Serving substrate: requests, queues, KV allocation, engine, simulator.

Online API surface (see README "Online API"):

* :class:`repro.serving.engine.EngineCore` — step-based core
  (``add_request`` / ``abort`` / ``step`` / ``has_work``), emitting
  :class:`repro.serving.engine.EngineEvent` per round.
* :class:`repro.serving.server.InferenceServer` — streaming submit/cancel
  frontend with named SLO classes (``interactive``/``standard``/``batch``).
* ``EngineCore.serve()`` — offline compatibility wrapper (full request list
  in, blocking, identical greedy tokens and readback count).

(Import from the submodules directly — ``repro.core.scheduler`` imports
``repro.serving.request``, so re-exporting the engine here would close an
import cycle.)
"""
