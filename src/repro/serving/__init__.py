"""Serving substrate: requests, queues, KV allocation, engine, simulator."""
