"""Real-execution serving engine: SlidingServe driving actual JAX forwards.

This is the end-to-end integration of the paper's scheduler with the model
substrate: continuous batching over a slot-based KV cache, chunked prefill
via ``chunk_prefill_step`` (shape-bucketed so JIT caches stay warm), lockstep
ragged decode via ``decode_step``, wall-clock latencies feeding the online
predictor. On CPU it serves the reduced-config models (the examples use it);
on TPU the same loop drives the sharded step functions with the Pallas
kernels underneath.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.scheduler import SchedulerBase
from repro.models.model import (RunCtx, chunk_prefill_step, decode_step,
                                init_cache, init_params)
from repro.serving.request import ReqState, Request


def _bucket(n: int, buckets=(16, 32, 64, 128, 256, 512)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclasses.dataclass
class EngineStats:
    iterations: int = 0
    prefill_calls: int = 0
    decode_calls: int = 0
    compiled_shapes: int = 0


class ServingEngine:
    """Slot-based continuous batching engine executing a real model."""

    def __init__(self, cfg: ModelConfig, scheduler: SchedulerBase, *,
                 max_slots: int = 8, max_len: int = 512,
                 rctx: Optional[RunCtx] = None, seed: int = 0):
        self.cfg = cfg
        self.sched = scheduler
        self.max_slots = max_slots
        self.max_len = max_len
        self.rctx = rctx or RunCtx(block_q=32, block_k=32, mlstm_block=32)
        self.params = init_params(cfg, jax.random.PRNGKey(seed))
        self.cache = init_cache(cfg, max_slots, max_len)
        self.lengths = np.zeros((max_slots,), np.int32)   # cached tokens/slot
        self.slot_of: Dict[int, int] = {}
        self.free_slots = list(range(max_slots))
        self.stats = EngineStats()
        self._jit_chunk = {}
        rctx = self.rctx

        def decode_merged(params, tokens, cache, lengths_p1, keep_mask):
            # run one decode step for every slot, then keep the updated cache
            # only for rows that are really decoding (others' recurrent
            # state / KV must not be touched by their padding tokens)
            logits, new_cache = decode_step(cfg, params, tokens, cache, 0,
                                            rctx=rctx, lengths=lengths_p1)
            def merge(new, old):
                m = keep_mask.reshape((1, -1) + (1,) * (new.ndim - 2))
                return jnp.where(m, new, old)
            merged = jax.tree.map(merge, new_cache, cache)
            return logits, merged

        self._jit_decode = jax.jit(decode_merged, donate_argnums=(2,))

        def chunk_one(params, tokens, cache, start, slot, last_idx):
            # slice out the slot's cache row, run the chunk at offset
            # ``start``, and write the row back — other slots untouched.
            sub = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, 1), cache)
            logits, new_sub = chunk_prefill_step(cfg, params, tokens, sub,
                                                 start, rctx=rctx,
                                                 logits_at=last_idx)
            merged = jax.tree.map(
                lambda full, row: jax.lax.dynamic_update_slice_in_dim(
                    full, row.astype(full.dtype), slot, 1),
                cache, new_sub)
            return logits, merged

        self._chunk_one = chunk_one
        self._tokens_out: Dict[int, List[int]] = {}

    # ---- slot management -----------------------------------------------------
    def _assign_slot(self, req: Request) -> Optional[int]:
        if req.rid in self.slot_of:
            return self.slot_of[req.rid]
        if not self.free_slots:
            return None
        s = self.free_slots.pop()
        self.slot_of[req.rid] = s
        self.lengths[s] = 0
        return s

    def _release(self, req: Request) -> None:
        s = self.slot_of.pop(req.rid, None)
        if s is not None:
            self.free_slots.append(s)

    # ---- model execution -------------------------------------------------------
    def _chunk_fn(self, chunk_len: int):
        key = chunk_len
        if key not in self._jit_chunk:
            self._jit_chunk[key] = jax.jit(self._chunk_one,
                                           donate_argnums=(2,))
            self.stats.compiled_shapes += 1
        return self._jit_chunk[key]

    def _run_prefill_chunk(self, req: Request, n: int,
                           prompt_tokens: np.ndarray) -> None:
        slot = self.slot_of[req.rid]
        start = int(self.lengths[slot])
        n = min(n, req.prompt_len - start)
        from repro.configs.base import MAMBA, MLSTM, SLSTM
        recurrent = any(k in (MAMBA, MLSTM, SLSTM) for k in self.cfg.layer_pattern)
        # recurrent state advances per token, so padding tokens would pollute
        # it — recurrent archs use exact-length chunks (more JIT shapes, fine)
        blen = n if recurrent else _bucket(n)
        n = min(n, blen)
        chunk = np.zeros((1, blen), np.int32)
        real = prompt_tokens[start:start + n]
        chunk[0, :n] = real
        # bucket padding: repeat the last real token (masked out afterwards by
        # restoring the true length; attention past ``start+blen`` is causal)
        if n < blen and n > 0:
            chunk[0, n:] = real[-1]
        fn = self._chunk_fn(blen)
        logits, self.cache = fn(self.params, jnp.asarray(chunk), self.cache,
                                start, slot, n - 1)
        self.lengths[slot] = start + n
        self.stats.prefill_calls += 1
        if start + n >= req.prompt_len:
            tok = int(jnp.argmax(logits[0]))
            self._tokens_out.setdefault(req.rid, []).append(tok)

    def _run_decode(self, reqs: Sequence[Request]) -> None:
        tokens = np.zeros((self.max_slots, 1), np.int32)
        keep = np.zeros((self.max_slots,), bool)
        for r in reqs:
            slot = self.slot_of[r.rid]
            prev = self._tokens_out.get(r.rid, [0])
            tokens[slot, 0] = prev[-1] if prev else 0
            keep[slot] = True
        lengths_p1 = self.lengths + 1   # every row writes to its empty spot
        logits, self.cache = self._jit_decode(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.asarray(lengths_p1), jnp.asarray(keep))
        for r in reqs:
            slot = self.slot_of[r.rid]
            self.lengths[slot] += 1
            tok = int(jnp.argmax(logits[slot]))
            self._tokens_out.setdefault(r.rid, []).append(tok)
        self.stats.decode_calls += 1

    # ---- main loop ----------------------------------------------------------------
    def serve(self, requests: Sequence[Request],
              prompts: Optional[Dict[int, np.ndarray]] = None,
              max_wall_s: float = 300.0) -> Dict:
        """Serve requests (arrival times are wall-clock offsets from start)."""
        rng = np.random.default_rng(0)
        prompts = prompts or {
            r.rid: rng.integers(0, self.cfg.vocab_size, r.prompt_len).astype(np.int32)
            for r in requests
        }
        t0 = time.perf_counter()
        pending = sorted(requests, key=lambda r: r.arrival)
        active: List[Request] = []
        done: List[Request] = []

        def now() -> float:
            return time.perf_counter() - t0

        while (pending or active) and now() < max_wall_s:
            while pending and pending[0].arrival <= now():
                r = pending.pop(0)
                if self._assign_slot(r) is None:
                    pending.insert(0, r)
                    break
                active.append(r)
            if not active:
                if pending:
                    time.sleep(max(pending[0].arrival - now(), 0.0) + 1e-4)
                continue

            prefilling = [r for r in active
                          if r.state in (ReqState.WAITING, ReqState.PREFILLING)]
            decoding = [r for r in active if r.state == ReqState.DECODING]
            decision = self.sched.schedule(now(), [], prefilling, decoding)
            if decision is None:
                time.sleep(1e-3)
                continue

            it0 = time.perf_counter()
            decode_reqs = [r for r, n in decision.alloc
                           if r.state == ReqState.DECODING]
            if decode_reqs:
                self._run_decode(decode_reqs)
            for r, n in decision.alloc:
                if r.state != ReqState.DECODING:
                    self._run_prefill_chunk(r, n, prompts[r.rid])
            latency = time.perf_counter() - it0
            t_now = now()
            self.stats.iterations += 1

            for r, n in decision.alloc:
                if r.state == ReqState.DECODING:
                    r.emit_token(t_now)
                else:
                    r.advance_prefill(n)
                    if r.remaining_prefill() == 0:
                        r.emit_token(t_now)
                if r.state == ReqState.FINISHED:
                    self._release(r)
                    active.remove(r)
                    done.append(r)
            self.sched.observe(decision.batch(), latency)

        return {
            "finished": done,
            "unfinished": [r for r in requests if r.state != ReqState.FINISHED],
            "stats": self.stats,
            "outputs": dict(self._tokens_out),
            "wall": now(),
        }
