"""Real-execution serving engine: SlidingServe driving actual JAX forwards.

This is the end-to-end integration of the paper's scheduler with the model
substrate. Two cache designs share one serve loop:

* **paged** (default where the arch allows) — the production layout. KV lives
  in physical pages handed out by :class:`BlockAllocator`, which is the
  single admission/preemption authority (admit on free blocks, grow per
  emitted token, evict-and-recompute the lowest-priority owner when decode
  growth fails). A scheduler ``Decision`` within the row ladder executes as
  at most **two** fused JIT dispatches no matter how many requests it names
  (row counts above ``ROW_BUCKETS[-1]`` and chunks above the top chunk
  bucket split across extra dispatches): one ragged
  chunked-prefill batch (every prefill row at its own offset, vLLM-style
  slot-mapped page writes, the ``paged_prefill_attention`` kernel on TPU)
  and one ragged decode batch (``paged_attention`` kernel on TPU; both fall
  back to their jnp oracles on CPU). Concurrency is bounded by KV pages, not
  by a slot count, and KV pressure (`utilization`, evictions) is surfaced to
  ``SchedulerBase.schedule/observe`` so chunk budgets back off before
  allocation failures.

  The paged hot path is **zero-sync**: both fused steps sample greedily *on
  device* and return int32 token ids, the serve loop runs one round ahead of
  the device (JAX async dispatch), and the only device→host transfer is a
  single deferred token-id readback per scheduler round — round N's ids are
  pulled while round N+1's admission, scheduling and numpy batch assembly
  have already happened on the host. Block-table uploads are content-cached
  and reused across rounds. ``overlap=False`` restores the legacy
  sync-every-row behaviour for A/B profiling (``bench_goodput
  --profile-overhead``).
* **slot** (fallback for recurrent/MLA/enc-dec archs whose per-request state
  is not paged) — contiguous ``max_slots x max_len`` rows, per-request
  chunked prefill and lockstep ragged decode, as in the original engine.

  The paged executor is **mesh-aware**: pass ``mesh=`` (see
  ``launch/mesh.make_serving_mesh``) and the fused steps run under
  ``jax.jit`` + ``shard_map``. KV page pools shard attention heads on the
  ``model`` axis when the head count divides it, else stay replicated with
  sequence-sharded attention (``launch/sharding.paged_cache_specs`` mirrors
  the training cache rule); params and every host-derived operand — block
  tables, write slots, token ids — replicate, so the scheduler stack needs
  zero changes and greedy tokens are bit-identical to the single-device
  engine. The one-readback-per-round and ROW_BUCKETS invariants survive
  unchanged (token ids come back as one replicated [R] vector).

Wall-clock latencies feed the online predictor in both modes (paged observes
one round late, at the readback that proves the round finished). On CPU the
engine serves the reduced-config models (the examples use it); on TPU the
same loop drives the sharded step functions with the Pallas kernels
underneath.

The engine is **step-based** (vLLM/sglang-style online core): requests enter
continuously via ``add_request(req, prompt)``, leave via ``abort(rid)``, and
``step()`` runs exactly one scheduler round — admission, scheduling, the
fused dispatches, and the deferred one-readback-per-round flush — returning
the round's :class:`EngineEvent` list (QUEUED / ADMITTED / FIRST_TOKEN /
TOKEN / FINISHED / EVICTED / ABORTED, each with a timestamp and, for
token-bearing events, the token id). Token-bearing events of a paged round
surface one ``step()`` late, at the flush that reads the round's ids back.
``serve()`` is a thin offline compatibility wrapper that feeds a request
list through the same ``step()`` loop; ``repro.serving.server`` hosts the
streaming submit/cancel frontend.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import heapq
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import MAMBA, MLSTM, SLSTM, ModelConfig
from repro.core.scheduler import KVPressure, SchedulerBase
from repro.models.model import (PAGED_KV_LAYOUT, RunCtx, Sampling,
                                chunk_prefill_step, decode_step, init_cache,
                                init_paged_cache, init_params,
                                paged_chunk_step, paged_decode_step,
                                paged_spec_step, supports_paged_cache)
from repro.serving.block_allocator import BlockAllocator
from repro.serving.drafter import DrafterBase, NGramDrafter
from repro.serving.request import ReqState, Request

# chunk-length ladder for JIT shape bucketing; allocations above the top rung
# are split across dispatches instead of being silently truncated.
CHUNK_BUCKETS = (16, 32, 64, 128, 256, 512, 1024, 2048)

# fused-batch *row* ladder: like CHUNK_BUCKETS but for the batch dimension.
# Row counts above the top rung are split across dispatches, so the set of
# compiled row shapes is bounded by this tuple no matter how high concurrency
# climbs (an unbounded next-pow2 ladder mints a fresh XLA program for every
# new power of two it meets).
ROW_BUCKETS = (1, 2, 4, 8, 16, 32, 64)


def _bucket(n: int, buckets=CHUNK_BUCKETS) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def _row_bucket(n: int) -> int:
    return _bucket(n, ROW_BUCKETS)


def _pow2(n: int, lo: int = 1) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class EventKind(enum.Enum):
    QUEUED = "queued"            # request handed to the engine (arrival)
    ADMITTED = "admitted"        # KV/slot reserved; request is executable
    FIRST_TOKEN = "first_token"  # first output token (token id attached)
    TOKEN = "token"              # subsequent output token (token id attached)
    FINISHED = "finished"        # reason: "length" (max_output) | "stop" (EOS)
    EVICTED = "evicted"          # relegated by KV pressure; will re-prefill
    ABORTED = "aborted"          # cancelled via EngineCore.abort()


@dataclasses.dataclass
class EngineEvent:
    """One request-lifecycle transition, as observed by ``step()``.

    ``t`` is seconds on the engine clock (``EngineCore.now()``). For
    FIRST_TOKEN/TOKEN it is the *readback* time — when the id became
    host-visible — which for overlapped paged rounds is one round after
    dispatch."""

    kind: EventKind
    rid: int
    t: float
    token: Optional[int] = None
    reason: str = ""


@dataclasses.dataclass
class EngineStats:
    iterations: int = 0
    prefill_calls: int = 0        # fused chunk dispatches (paged) / per-req (slot)
    decode_calls: int = 0
    compiled_shapes: int = 0
    evictions: int = 0
    aborted: int = 0              # requests cancelled via abort()
    max_concurrency: int = 0      # peak simultaneously-admitted requests
    max_round_calls: int = 0      # peak model dispatches in one scheduler round
    # ---- prefix-cache accounting (paged mode) --------------------------------
    cache_hit_tokens: int = 0     # prompt tokens served from frozen pages
    prompt_tokens: int = 0        # prompt tokens admitted (hit-rate denominator)
    prefill_tokens: int = 0       # prompt tokens actually computed
    decode_tokens: int = 0        # output tokens computed (decode-step rows)
    deferred_admissions: int = 0  # admission rounds a follower waited for an
                                  # in-flight leader to commit a shared prefix
    # ---- zero-sync hot-path accounting (paged mode) --------------------------
    token_readbacks: int = 0      # device->host token-id transfers
    sync_s: float = 0.0           # wall time blocked waiting on the device
    dispatch_s: float = 0.0       # wall time issuing (async) model dispatches
    device_busy_s: float = 0.0    # wall covered by an in-flight round
    host_s: float = 0.0           # wall with NO round in flight: unhidden
                                  # host work + idle (the overlap target -> 0)
    reused_uploads: int = 0       # block-table uploads served from device cache
    # ---- speculative decoding (paged mode, spec_k > 0) -----------------------
    spec_calls: int = 0           # fused verify dispatches
    spec_rounds: int = 0          # rounds that dispatched >=1 verify row
    spec_rows: int = 0            # verify rows read back
    spec_drafts: int = 0          # draft tokens proposed (verify width - 1)
    spec_accepted: int = 0        # draft tokens the model accepted
    spec_emitted: int = 0         # tokens emitted by verify rows (accepted
                                  # drafts + bonus, after stop/length cuts)
    # ---- per-SLO-class breakdown (admission/eviction weight the class) ------
    finished_by_class: Dict[str, int] = dataclasses.field(default_factory=dict)
    evicted_by_class: Dict[str, int] = dataclasses.field(default_factory=dict)
    aborted_by_class: Dict[str, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _InflightRound:
    """One dispatched-but-not-read-back scheduler round (paged mode)."""

    toks: List                    # device int32 vectors, one per dispatch
    emits: List[Tuple[int, int]]  # (rid, row in the concatenated tok vector)
    t_dispatch: float             # perf_counter at dispatch
    executed_batch: List = dataclasses.field(default_factory=list)
    # (req, token index, was_first, was_finish): timestamps provisionally
    # stamped at dispatch, corrected to readback time at flush.
    stamped: List = dataclasses.field(default_factory=list)
    # speculative verify rows: (rid, base offset of the row's [accepted,
    # out_0..out_{Lb-1}] span in the concatenated vector, Lb, n_real, start).
    spec_emits: List[Tuple[int, int, int, int, int]] = \
        dataclasses.field(default_factory=list)


class EngineCore:
    """Continuous-batching engine core executing a real model, driven one
    scheduler round at a time.

    Lifecycle: ``add_request(req, prompt)`` → ``step()`` (repeat while
    ``has_work()``) → per-round ``EngineEvent`` lists. ``abort(rid)`` cancels
    a request at any stage, releasing its KV pages / slot immediately.
    ``serve(requests)`` is the offline compatibility wrapper over the same
    loop (identical greedy tokens, identical readback count).

    ``cache_mode``: ``"paged"`` | ``"slot"`` | ``"auto"`` (paged where the
    architecture supports it — see ``supports_paged_cache``).
    ``overlap``: paged mode only — run the one-step-lookahead pipeline
    (default). ``False`` syncs every round immediately with per-row token
    transfers, reproducing the pre-zero-sync hot path for profiling.
    ``mesh``: paged mode only — run the fused steps sharded (see the module
    docstring); ``None`` is the exact single-device engine. Slot mode
    ignores it (recurrent/MLA archs stay single-device).
    ``prefix_cache``: paged mode only — reuse frozen full pages across
    requests sharing a token prefix (system prompts, multi-turn). Admission
    consults ``BlockAllocator.match_prefix`` and prefill starts *after* the
    matched prefix; fully-written pages are committed (frozen) into the
    content index as prefill/decode advances. Greedy tokens are bit-identical
    with the cache on or off — cached K/V pages hold exactly the values
    recompute would produce (K/V are per-token projections, independent of
    chunking), so only the amount of prefill work changes.
    """

    def __init__(self, cfg: ModelConfig, scheduler: SchedulerBase, *,
                 cache_mode: str = "auto",
                 max_slots: int = 8, max_len: int = 512,
                 kv_capacity_tokens: Optional[int] = None,
                 page_size: int = 16, decode_reserve_tokens: int = 64,
                 overlap: bool = True, mesh=None, prefix_cache: bool = True,
                 defer_shared: bool = True,
                 spec_k: int = 0, drafter: Optional[DrafterBase] = None,
                 spec_class_caps: Optional[Dict[int, int]] = None,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0,
                 rctx: Optional[RunCtx] = None, seed: int = 0):
        if cache_mode == "auto":
            cache_mode = "paged" if supports_paged_cache(cfg) else "slot"
        if cache_mode == "paged" and not supports_paged_cache(cfg):
            raise ValueError(
                f"{cfg.name}: paged KV requires pure-attention mixers; "
                f"use cache_mode='slot'")
        self.cache_mode = cache_mode
        self.cfg = cfg
        self.sched = scheduler
        self.max_slots = max_slots
        self.max_len = max_len
        self.overlap = overlap
        self.rctx = rctx or RunCtx(block_q=32, block_k=32, mlstm_block=32)
        # the mesh applies to the paged executor only; slot mode (recurrent /
        # MLA archs) stays single-device and quietly ignores an env override.
        self.mesh = mesh if cache_mode == "paged" else None
        self._repl: Optional[NamedSharding] = None
        self._cache_shardings = None
        self.params = init_params(cfg, jax.random.PRNGKey(seed))
        self.stats = EngineStats()
        self._tokens_out: Dict[int, List[int]] = {}
        self._seen_shapes = set()
        self._resumed: set = set()    # evicted mid-decode; re-prefill, no emit
        self._round_calls = 0
        self._last_round_evictions = 0
        self._t0 = time.perf_counter()

        # ---- step-API state (the former serve()-loop locals) ----------------
        self._pending: List[Tuple[float, int, Request]] = []  # future arrivals
        self._seq = 0                                  # heap tie-break counter
        self._queued: collections.deque = collections.deque()  # arrived, no KV
        self._active: List[Request] = []                        # KV-resident
        self._done: List[Request] = []                          # FINISHED
        self._aborted: List[Request] = []                       # ABORTED
        self._prompts: Dict[int, np.ndarray] = {}
        self._reqs: Dict[int, Request] = {}     # rid -> live (unretired) req
        self._events: List[EngineEvent] = []
        self._progress = "idle"   # what the last step() did: "executed" |
                                  # "empty" | "no-decision" | "idle"
        self._inflight: Optional[_InflightRound] = None

        # ---- speculative decoding + sampling policy --------------------------
        # spec_k > 0 turns decode-eligible rows into multi-token verify rows
        # (paged mode only; slot mode ignores it). Per-class caps bound the
        # draft budget by SLO-class rank; interactive (rank 0) drops to plain
        # decode under KV pressure — verify rows cost fixed compute for a
        # variable token yield, exactly the trade a latency-critical class
        # should not make when the system is already strained.
        self.spec_k = int(spec_k) if cache_mode == "paged" else 0
        self.drafter = drafter or (NGramDrafter() if self.spec_k else None)
        self.spec_class_caps = dict(spec_class_caps or {})
        self.sampling = (Sampling(temperature=temperature, top_k=top_k,
                                  seed=sample_seed)
                         if temperature > 0 else None)
        self._sample_nonce = 0          # monotonic per-dispatch RNG fold
        self._round_spec_rids: set = set()
        self._spec_acc_mean = 0.0       # EMA of accepted length per verify row
        self._spec_acc_m2 = 0.0         # EMA of its square (for the std)
        self._spec_draft_ema = 0.0      # EMA of drafts per decode-eligible row

        self.prefix_cache = bool(prefix_cache) and cache_mode == "paged"
        # dependency-aware admission defer (in-flight burst sharing): when K
        # concurrent requests share an uncommitted prefix, followers wait for
        # the leader to commit the shared pages instead of prefilling the
        # prefix K times (sglang-style). Only meaningful with the prefix
        # cache on — without an index there is nothing to wait for.
        self.defer_shared = bool(defer_shared) and self.prefix_cache
        self._defer_rounds: Dict[int, int] = {}   # rid -> rounds deferred
        self._defer_cap = 512                     # livelock safety valve
        if cache_mode == "paged":
            capacity = kv_capacity_tokens or max_slots * max_len
            self.alloc = BlockAllocator(capacity, page_size)
            self.page_size = page_size
            self.decode_reserve = decode_reserve_tokens
            # one extra physical page (the last) is the trash page: padding
            # tokens' KV writes land there and are never read back.
            self.cache = init_paged_cache(cfg, self.alloc.num_blocks + 1,
                                          page_size)
            self._trash_slot = self.alloc.num_blocks * page_size
            self._length: Dict[int, int] = {}     # tokens resident per rid
            self._folded: Dict[int, int] = {}     # gen tokens folded on evict
            self._dev_cache: Dict[Tuple, Tuple[np.ndarray, jnp.ndarray]] = {}
            jit_kw = {}
            if self.mesh is not None:
                self._init_mesh_state(cfg)
                # pin the outputs: token ids replicated (the one host-visible
                # artifact per round), cache exactly on the input shardings so
                # donation stays a same-layout buffer reuse.
                jit_kw["out_shardings"] = (self._repl, self._cache_shardings)
            rctx_ = self.rctx
            sampling = self.sampling
            # ``nonce`` is a traced int32 scalar so changing it never
            # retraces; with greedy sampling it is dead code and XLA drops it.

            def chunk_fused(params, tokens, cache, row_pos, row_lens, bt, ws,
                            logits_at, nonce):
                return paged_chunk_step(cfg, params, tokens, cache, row_pos,
                                        rctx=rctx_, row_lens=row_lens,
                                        block_tables=bt, write_slots=ws,
                                        logits_at=logits_at,
                                        sampling=sampling, nonce=nonce)

            def decode_fused(params, tokens, cache, lengths, bt, ws, nonce):
                return paged_decode_step(cfg, params, tokens, cache,
                                         rctx=rctx_, lengths=lengths,
                                         block_tables=bt, write_slots=ws,
                                         sampling=sampling, nonce=nonce)

            def spec_fused(params, tokens, cache, row_pos, row_lens, bt, ws,
                           nonce):
                return paged_spec_step(cfg, params, tokens, cache, row_pos,
                                       rctx=rctx_, row_lens=row_lens,
                                       block_tables=bt, write_slots=ws,
                                       sampling=sampling, nonce=nonce)

            self._jit_chunk_fused = jax.jit(chunk_fused, donate_argnums=(2,),
                                            **jit_kw)
            self._jit_decode_fused = jax.jit(decode_fused, donate_argnums=(2,),
                                             **jit_kw)
            self._jit_spec_fused = jax.jit(spec_fused, donate_argnums=(2,),
                                           **jit_kw)
        else:
            self._init_slot_mode(cfg, max_slots, max_len)

    # =========================================================================
    # sharded paged executor (jit + shard_map on a mesh)
    # =========================================================================
    def _init_mesh_state(self, cfg: ModelConfig) -> None:
        """Place the paged model state on the mesh: params and host-derived
        operands replicate (dense math is identical on every device — the
        bit-identity guarantee), while the KV page pools shard attention
        heads on the ``model`` axis when the head count divides it
        (``launch/sharding.py``'s cache rule; otherwise the pools stay
        replicated and the attention ops sequence-shard the computation).
        The scheduler stack never sees any of this — block tables and token
        ids stay replicated host-side state."""
        from repro.launch.sharding import paged_cache_specs
        mesh = self.mesh
        axis = self.rctx.shard_axis
        self.rctx = dataclasses.replace(self.rctx, mesh=mesh)
        self._repl = NamedSharding(mesh, P())
        self.params = jax.device_put(self.params, self._repl)
        shapes = jax.eval_shape(lambda c: c, self.cache)
        specs = paged_cache_specs(cfg, shapes, mesh, axis=axis)
        self._cache_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        self.cache = jax.tree.map(jax.device_put, self.cache,
                                  self._cache_shardings)

    def _to_dev(self, arr) -> jnp.ndarray:
        """Host->device upload; replicated across the mesh when sharded (the
        engine's host state — tokens, tables, slots — is mesh-invariant)."""
        if self._repl is not None:
            return jax.device_put(arr, self._repl)
        return jnp.asarray(arr)

    def kv_shards(self) -> int:
        """How many ways the KV page pools are partitioned (1 = replicated
        or single-device); the shared ``head_shards`` rule, so this always
        agrees with cache placement and ops dispatch."""
        from repro.kernels.shard_utils import head_shards
        if self.cache_mode != "paged" or self.mesh is None:
            return 1
        return head_shards(self.cfg.num_kv_heads, self.mesh,
                           self.rctx.shard_axis)

    def shard_info(self) -> Dict:
        """Mesh + per-shard KV-pool accounting (BENCH_goodput.json record)."""
        if self.cache_mode != "paged":
            return {"mesh": None, "kv_partition": "none", "kv_shards": 1}
        mesh = self.mesh
        shards = self.kv_shards()
        m = 1 if mesh is None else int(mesh.shape.get(self.rctx.shard_axis, 1))
        # a 1-wide (or absent) shard axis runs the exact single-device
        # dispatch — report it as unpartitioned, not as a trivial head shard.
        if shards > 1:
            partition = "heads"
        elif m > 1:
            partition = "sequence"
        else:
            partition = "none"
        info = {
            "mesh": None if mesh is None else "x".join(
                str(mesh.shape[a]) for a in mesh.axis_names),
            "axes": None if mesh is None else dict(mesh.shape),
            "kv_partition": partition,
            "kv_shards": shards,
            "kv_heads_per_shard": self.cfg.num_kv_heads // shards,
        }
        info.update(self.alloc.shard_stats(shards))
        return info

    def shard_banner(self) -> str:
        """One-line human-readable form of :meth:`shard_info` (the serving
        entrypoints print it instead of each formatting their own)."""
        info = self.shard_info()
        return (f"sharded paged executor: mesh={info['mesh']} "
                f"kv_partition={info['kv_partition']} "
                f"shards={info['kv_shards']}")

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def now(self) -> float:
        """Seconds on the engine clock (what event timestamps and request
        arrivals are measured against)."""
        return self._now()

    # =========================================================================
    # step API: add_request / abort / step / has_work
    # =========================================================================
    @staticmethod
    def _bump(d: Dict[str, int], cls: str) -> None:
        d[cls] = d.get(cls, 0) + 1

    def _event(self, kind: EventKind, rid: int, t: float,
               token: Optional[int] = None, reason: str = "") -> None:
        self._events.append(EngineEvent(kind, rid, t, token, reason))

    def _drain_events(self) -> List[EngineEvent]:
        evts, self._events = self._events, []
        return evts

    def add_request(self, req: Request, prompt: Sequence[int]) -> None:
        """Hand a request to the engine. ``req.arrival`` is on the engine
        clock: a future arrival is held back (the offline wrapper's replayed
        traces), a past/now arrival joins the admission queue immediately."""
        assert req.rid not in self._reqs, f"duplicate rid {req.rid}"
        # transcripts (_tokens_out) outlive retirement — serve() exposes
        # them — so recycling a finished request's rid would splice two
        # streams together (and feed the old stream's last token into the
        # new stream's first decode). Fail loudly instead.
        assert req.rid not in self._tokens_out, \
            f"rid {req.rid} reuses a finished request's id on this engine"
        self._reqs[req.rid] = req
        self._prompts[req.rid] = np.asarray(prompt, np.int32)
        if req.arrival > self._now():
            heapq.heappush(self._pending, (req.arrival, self._seq, req))
            self._seq += 1
        else:
            self._queued.append(req)
            self._event(EventKind.QUEUED, req.rid, self._now())

    def abort(self, rid: int) -> List[EngineEvent]:
        """Cancel a request at any stage: drop it from the arrival/admission
        queues, or free its KV pages / slot if it is mid-prefill or
        mid-decode. Returns the events this produced (the in-flight round is
        flushed first when it references the request, so its final TOKEN
        events surface here too)."""
        r = self._reqs.get(rid)
        if r is None or r.state in (ReqState.FINISHED, ReqState.ABORTED):
            return []
        if any(e[2].rid == rid for e in self._pending):
            self._pending = [e for e in self._pending if e[2].rid != rid]
            heapq.heapify(self._pending)
        try:
            self._queued.remove(r)
        except ValueError:
            pass
        if r in self._active:
            # settle the in-flight round first when it will *emit* for this
            # request (this is that round's one readback happening early, not
            # an extra sync). A non-emitting row — mid-prefill, or a WAITING
            # request with no row at all — needs no flush: its page writes
            # land before any later owner of the pages writes them.
            fr = self._inflight
            if fr is not None and (any(x == rid for x, _ in fr.emits)
                                   or any(s[0] == rid for s in fr.spec_emits)):
                self._flush_round()
                if r.state == ReqState.FINISHED:  # the flush finished it (stop)
                    return self._drain_events()
        r.state = ReqState.ABORTED
        r.finish_time = self._now()
        self._retire(r)
        self._aborted.append(r)
        self.stats.aborted += 1
        self._bump(self.stats.aborted_by_class, r.slo_class)
        self._event(EventKind.ABORTED, rid, self._now())
        return self._drain_events()

    def has_work(self) -> bool:
        """True while any request is pending/queued/active or a dispatched
        round still awaits its readback (the final tokens)."""
        return bool(self._pending or self._queued or self._active
                    or self._inflight is not None)

    @property
    def progress(self) -> str:
        """What the last ``step()`` accomplished: ``"executed"`` (a round
        ran), ``"empty"`` (decision evicted away), ``"no-decision"``, or
        ``"idle"`` — drivers use this to pace sleeps and detect wedges."""
        return self._progress

    def next_arrival(self) -> Optional[float]:
        """Engine-clock time of the earliest not-yet-due request, or None.
        Idle drivers sleep until this instead of polling."""
        return self._pending[0][0] if self._pending else None

    @property
    def queue_depth(self) -> int:
        """Requests that have arrived but hold no KV yet (admission queue)."""
        return len(self._queued)

    def outstanding_tokens(self) -> int:
        """Token-work the engine still owes across every live request:
        uncomputed prompt tokens plus remaining output budget. This is the
        router's load signal — queue depth weighted by per-request estimated
        cost — so it counts queued *and* active requests (queued work is
        exactly what a newly routed request would wait behind)."""
        tot = 0
        for r in self._reqs.values():
            if r.state in (ReqState.FINISHED, ReqState.ABORTED):
                continue
            tot += r.remaining_prefill() + max(r.max_output - r.generated, 0)
        return tot

    def class_queue_depth(self, max_rank: int) -> int:
        """Live requests at SLO-class rank ``max_rank`` or more critical —
        the work a new request of that rank would queue behind (the router's
        class-aware tie-break: interactive must not queue behind batch)."""
        return sum(1 for r in self._reqs.values()
                   if r.state not in (ReqState.FINISHED, ReqState.ABORTED)
                   and r.class_rank() <= max_rank)

    @property
    def last_round_evictions(self) -> int:
        """Evictions the most recent executed round caused (wedge guards use
        this: an empty round that also evicted nothing cannot make progress
        by itself)."""
        return self._last_round_evictions

    def stalled(self) -> bool:
        """Wedge predicate shared by every driver: the last ``step()`` made
        no progress and nothing external will change that — an empty round
        that evicted nothing (a request outgrew total capacity), or an idle
        engine holding queued-but-unadmittable work with no future arrivals.
        Drivers bail after a few consecutive True results instead of
        spinning to their wall clock."""
        if self._progress == "empty" and self._last_round_evictions == 0:
            return True
        return (self._progress == "idle" and not self._pending
                and bool(self._queued))

    def flush(self) -> List[EngineEvent]:
        """Settle any in-flight round now (its one readback happens early,
        not extra) and return the events that surfaced. Drivers call this on
        abnormal exits (wall budget, wedge) so the final round's tokens are
        never stranded on device."""
        self._flush_round()
        return self._drain_events()

    def _retire(self, r: Request) -> None:
        """Release a request's execution resources (idempotent)."""
        if self.cache_mode == "paged":
            if r.rid in self.alloc.owners:
                self.alloc.free(r.rid)
            self._length.pop(r.rid, None)
            self._folded.pop(r.rid, None)
        else:
            self._release_slot(r)
        self._resumed.discard(r.rid)
        self._defer_rounds.pop(r.rid, None)
        if r in self._active:
            self._active.remove(r)
        self._reqs.pop(r.rid, None)
        # drop the prompt array — the dominant per-request memory. Token
        # transcripts (_tokens_out) and the _done list are intentionally
        # kept: serve()'s return contract exposes them after retirement.
        self._prompts.pop(r.rid, None)

    # ---- in-flight burst sharing (dependency-aware admission defer) ----------
    def _shared_whole_pages(self, a: np.ndarray, b: np.ndarray) -> int:
        """Whole pages of common prefix between two token arrays."""
        ps = self.page_size
        n = min(len(a), len(b)) // ps * ps
        if n == 0:
            return 0
        eq = a[:n] == b[:n]
        if eq.all():
            return n // ps
        return int(np.argmin(eq)) // ps

    def _defer_for_leader(self, r: Request) -> bool:
        """True when admitting ``r`` *now* would recompute a prefix that an
        in-flight leader is about to commit: some active request shares more
        whole prompt pages with ``r`` than the index can serve yet, and its
        commit pointer is still advancing toward them. Deferring the
        follower one round converts K concurrent prefills of a shared burst
        prefix into one prefill plus K-1 cache hits. The wait is bounded:
        the leader either commits the pages (the index match then covers
        them and the gain vanishes), or stops being eligible (finished /
        evicted / commit-stalled), or the per-rid round cap fires."""
        if not self.defer_shared:
            return False
        prompt = self._prompts[r.rid]
        # page-granular cap mirroring admission's match_limit: the last
        # prompt token is always computed, so pages past it can't be reused.
        cap = (r.prompt_len - 1) // self.page_size * self.page_size
        if cap == 0:
            return False
        matched_now = self.alloc.match_prefix(prompt,
                                              max_tokens=r.prompt_len - 1)[1]
        gain = 0
        for lead in self._active:
            if lead.state == ReqState.DECODING:
                continue    # prompt pages already committed (or stalled)
            if (lead.rid not in self.alloc.owners
                    or self.alloc.commit_stalled(lead.rid)):
                continue
            lp = self._prompts.get(lead.rid)
            if lp is None:
                continue
            shared = min(self._shared_whole_pages(prompt, lp)
                         * self.page_size, cap)
            if (shared > matched_now
                    and self.alloc.committed_count(lead.rid)
                    * self.page_size < shared):
                gain = max(gain, shared - matched_now)
        if gain >= self.page_size \
                and self._defer_rounds.get(r.rid, 0) < self._defer_cap:
            self._defer_rounds[r.rid] = self._defer_rounds.get(r.rid, 0) + 1
            self.stats.deferred_admissions += 1
            return True
        return False

    def _admit(self) -> None:
        """Move due arrivals into the admission queue, then admit while the
        free pool lasts (full-prompt + decode-reserve reservation). Admission
        order weights the request's named SLO class: latency-critical classes
        (``interactive``) go first, FIFO preserved within a class — a
        single-class workload therefore admits in exactly the legacy FIFO
        order (the stable sort is a no-op)."""
        paged = self.cache_mode == "paged"
        while self._pending and self._pending[0][0] <= self._now():
            _, _, r = heapq.heappop(self._pending)
            self._queued.append(r)
            self._event(EventKind.QUEUED, r.rid, r.arrival)
        # O(1) short-circuit: with the free pool exhausted no admission can
        # succeed, so skip the scan entirely (the common state while
        # saturated — this is what keeps admission off the hot path).
        exhausted = (self.alloc.free_blocks == 0 if paged
                     else not self.free_slots)
        if self._queued and not exhausted:
            if len(self._queued) > 1:
                self._queued = collections.deque(
                    sorted(self._queued, key=lambda r: r.class_rank()))
            failures = 0
            for _ in range(len(self._queued)):
                r = self._queued.popleft()
                if paged and self._defer_for_leader(r):
                    # burst sharing: wait for the in-flight leader's commit
                    # instead of prefilling the shared prefix again.
                    self._queued.append(r)
                    failures += 1
                    continue
                if paged:
                    # admission *reserves* the full prompt + decode headroom
                    # so concurrent admits are gated by the same free pool
                    # (admit(rid, 0) would let every fitting prompt in at
                    # once and convert admission control into evict thrash).
                    # With the prefix cache on, frozen pages matching the
                    # prompt are reused in place of fresh allocations — the
                    # match is capped at prompt_len - 1 so at least one
                    # prompt token is always computed for first-token logits.
                    need = r.remaining_prefill()
                    ok = self.alloc.admit(
                        r.rid, need + self.decode_reserve,
                        token_ids=(self._prompts[r.rid]
                                   if self.prefix_cache else None),
                        match_limit=r.prompt_len - 1)
                else:
                    ok = self._assign_slot(r) is not None
                if ok:
                    self._active.append(r)
                    self._defer_rounds.pop(r.rid, None)
                    if paged:
                        matched = self.alloc.cached_tokens(r.rid)
                        self._length[r.rid] = matched
                        if matched:
                            # prefill resumes after the frozen prefix: the
                            # whole scheduler stack (remaining_prefill,
                            # predictor features, chunk budgets) sees only
                            # the uncached remainder, while context_len
                            # still counts the reused tokens.
                            r.prefilled = matched
                            r.cached_prefix = matched
                        self.stats.cache_hit_tokens += matched
                        self.stats.prompt_tokens += r.prompt_len
                    self._event(EventKind.ADMITTED, r.rid, self._now())
                else:
                    self._queued.append(r)
                    failures += 1
                if paged and self.alloc.free_blocks == 0:
                    # pool just drained: rotate the failures back to the
                    # front so FIFO order survives the early exit.
                    self._queued.rotate(failures)
                    break
        self.stats.max_concurrency = max(self.stats.max_concurrency,
                                         len(self._active))

    def step(self) -> List[EngineEvent]:
        """Run one scheduler round: admit, schedule, dispatch (≤2 fused model
        calls in paged mode), and flush the *previous* round's deferred
        readback. Returns the events that settled during this call; an idle
        step (nothing admitted or schedulable) flushes any in-flight round so
        the final tokens always surface."""
        paged = self.cache_mode == "paged"
        self._admit()
        if not self._active:
            self._flush_round()         # device is idle anyway
            self._progress = "idle"
            return self._drain_events()

        # admitted-but-unstarted requests are offered as ``waiting`` so MLPS
        # ordering applies to them (they are executable immediately).
        waiting = [r for r in self._active if r.state == ReqState.WAITING]
        prefilling = [r for r in self._active
                      if r.state == ReqState.PREFILLING]
        decoding = [r for r in self._active if r.state == ReqState.DECODING]
        kv = self._kv_pressure() if paged else None
        decision = self.sched.schedule(self._now(), waiting, prefilling,
                                       decoding, kv=kv)
        if decision is None:
            self._flush_round()
            self._progress = "no-decision"
            return self._drain_events()

        self._round_calls = 0
        it0 = time.perf_counter()
        executed = (self._execute_paged(decision) if paged
                    else self._execute_slot(decision))
        if not executed:
            # every entry was evicted away (severe KV pressure): the driver
            # should yield so re-admission can make progress.
            self._flush_round()
            self._progress = "empty"
            return self._drain_events()
        self._progress = "executed"
        latency = time.perf_counter() - it0
        t_now = self._now()
        self.stats.iterations += 1
        self.stats.max_round_calls = max(self.stats.max_round_calls,
                                         self._round_calls)

        executed_batch = []
        stamped = []
        for entry in executed:
            r, n, ctx = entry[0], entry[1], entry[2]
            drafts = entry[3] if len(entry) > 3 else 0
            if r.state in (ReqState.FINISHED, ReqState.ABORTED):
                # finished by the flush inside execute (stop token): its row
                # this round was dead — nothing to advance or emit.
                continue
            # verify rows observe as (tokens, ctx, draft_tokens) triples so
            # the predictor prices their extra per-row work (features x8).
            executed_batch.append((n, ctx, drafts) if drafts else (n, ctx))
            emitted = False
            was_first = r.first_token_time is None
            if r.state == ReqState.DECODING:
                if paged and r.rid in self._round_spec_rids:
                    # speculative verify row: how many tokens it emits is
                    # decided on device; emission, stop handling and page
                    # rollback all happen at the flush, from the payload.
                    continue
                r.emit_token(t_now)
                self.stats.decode_tokens += 1
                emitted = True
            else:
                r.advance_prefill(n)
                if r.remaining_prefill() == 0:
                    if r.rid in self._resumed:
                        # re-prefill after eviction: the pending token was
                        # already emitted; resume decoding silently.
                        self._resumed.discard(r.rid)
                        r.state = ReqState.DECODING
                    else:
                        r.emit_token(t_now)
                        emitted = True
            if emitted:
                if paged:
                    # token value is on device; events settle at the flush.
                    stamped.append((r, len(r.token_times) - 1, was_first,
                                    r.state == ReqState.FINISHED))
                else:
                    # slot mode syncs per round: ids are host-visible now.
                    tok = self._tokens_out[r.rid][-1]
                    reason = "length"
                    if r.state != ReqState.FINISHED and r.hits_stop(tok):
                        r.state = ReqState.FINISHED
                        r.finish_time = t_now
                        reason = "stop"
                    self._event(EventKind.FIRST_TOKEN if was_first
                                else EventKind.TOKEN, r.rid, t_now, token=tok)
                    if r.state == ReqState.FINISHED:
                        self._event(EventKind.FINISHED, r.rid, t_now,
                                    reason=reason)
            if r.state == ReqState.FINISHED:
                self._retire(r)
                self._done.append(r)
                self._bump(self.stats.finished_by_class, r.slo_class)
        if paged:
            # readback + observe happen at the next round's flush; the
            # executed batch is recorded on the in-flight round so the
            # observation reflects what actually ran (post split/clamp).
            if self._inflight is not None:
                self._inflight.executed_batch = executed_batch
                self._inflight.stamped = stamped
            self.alloc.check_invariants()
            if not self.overlap:
                self._flush_round()
        else:
            # close the loop on what actually ran (post split/clamp), not
            # on what the decision asked for.
            self.sched.observe(executed_batch, latency, kv=None)
        return self._drain_events()

    # =========================================================================
    # slot mode (legacy contiguous rows; recurrent / MLA / enc-dec archs)
    # =========================================================================
    def _init_slot_mode(self, cfg: ModelConfig, max_slots: int, max_len: int):
        rctx = self.rctx
        self.cache = init_cache(cfg, max_slots, max_len)
        self.lengths = np.zeros((max_slots,), np.int32)   # cached tokens/slot
        self.slot_of: Dict[int, int] = {}
        self.free_slots = list(range(max_slots))
        self._jit_chunk = {}

        def decode_merged(params, tokens, cache, lengths_p1, keep_mask):
            # run one decode step for every slot, then keep the updated cache
            # only for rows that are really decoding (others' recurrent
            # state / KV must not be touched by their padding tokens)
            logits, new_cache = decode_step(cfg, params, tokens, cache, 0,
                                            rctx=rctx, lengths=lengths_p1)
            def merge(new, old):
                m = keep_mask.reshape((1, -1) + (1,) * (new.ndim - 2))
                return jnp.where(m, new, old)
            merged = jax.tree.map(merge, new_cache, cache)
            return logits, merged

        self._jit_decode = jax.jit(decode_merged, donate_argnums=(2,))

        def chunk_one(params, tokens, cache, start, slot, last_idx):
            # slice out the slot's cache row, run the chunk at offset
            # ``start``, and write the row back — other slots untouched.
            sub = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, 1), cache)
            logits, new_sub = chunk_prefill_step(cfg, params, tokens, sub,
                                                 start, rctx=rctx,
                                                 logits_at=last_idx)
            merged = jax.tree.map(
                lambda full, row: jax.lax.dynamic_update_slice_in_dim(
                    full, row.astype(full.dtype), slot, 1),
                cache, new_sub)
            return logits, merged

        self._chunk_one = chunk_one

    def _assign_slot(self, req: Request) -> Optional[int]:
        if req.rid in self.slot_of:
            return self.slot_of[req.rid]
        if not self.free_slots:
            return None
        s = self.free_slots.pop()
        self.slot_of[req.rid] = s
        self.lengths[s] = 0
        return s

    def _release_slot(self, req: Request) -> None:
        s = self.slot_of.pop(req.rid, None)
        if s is not None:
            self.free_slots.append(s)

    def _chunk_fn(self, chunk_len: int):
        key = chunk_len
        if key not in self._jit_chunk:
            self._jit_chunk[key] = jax.jit(self._chunk_one,
                                           donate_argnums=(2,))
            self.stats.compiled_shapes += 1
        return self._jit_chunk[key]

    def _run_prefill_chunk(self, req: Request, n: int,
                           prompt_tokens: np.ndarray) -> int:
        """Execute up to ``n`` prompt tokens; returns tokens actually run.
        Allocations above the top bucket are split across dispatches (never
        silently truncated — the caller advances by the returned count)."""
        slot = self.slot_of[req.rid]
        total = min(n, req.prompt_len - int(self.lengths[slot]))
        recurrent = any(k in (MAMBA, MLSTM, SLSTM)
                        for k in self.cfg.layer_pattern)
        done = 0
        while done < total:
            start = int(self.lengths[slot])
            step = min(total - done, CHUNK_BUCKETS[-1])
            # recurrent state advances per token, so padding tokens would
            # pollute it — recurrent archs use exact-length chunks (more JIT
            # shapes, fine)
            blen = step if recurrent else _bucket(step)
            chunk = np.zeros((1, blen), np.int32)
            real = prompt_tokens[start:start + step]
            chunk[0, :step] = real
            # bucket padding: repeat the last real token (masked out afterwards
            # by restoring the true length; attention past ``start+blen`` is
            # causal)
            if step < blen and step > 0:
                chunk[0, step:] = real[-1]
            fn = self._chunk_fn(blen)
            logits, self.cache = fn(self.params, jnp.asarray(chunk),
                                    self.cache, start, slot, step - 1)
            self.lengths[slot] = start + step
            self.stats.prefill_calls += 1
            self._round_calls += 1
            done += step
        if int(self.lengths[slot]) >= req.prompt_len and done > 0:
            tok = int(jnp.argmax(logits[0]))
            self._tokens_out.setdefault(req.rid, []).append(tok)
        return done

    def _run_decode_slot(self, reqs: Sequence[Request]) -> None:
        tokens = np.zeros((self.max_slots, 1), np.int32)
        keep = np.zeros((self.max_slots,), bool)
        for r in reqs:
            slot = self.slot_of[r.rid]
            prev = self._tokens_out.get(r.rid, [0])
            tokens[slot, 0] = prev[-1] if prev else 0
            keep[slot] = True
        lengths_p1 = self.lengths + 1   # every row writes to its empty spot
        logits, self.cache = self._jit_decode(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.asarray(lengths_p1), jnp.asarray(keep))
        for r in reqs:
            slot = self.slot_of[r.rid]
            self.lengths[slot] += 1
            tok = int(jnp.argmax(logits[slot]))
            self._tokens_out.setdefault(r.rid, []).append(tok)
        self.stats.decode_calls += 1
        self._round_calls += 1

    # =========================================================================
    # paged mode: allocator-backed admission / growth / eviction
    # =========================================================================
    def _kv_pressure(self) -> KVPressure:
        """Snapshot for the scheduler; ``evictions`` reports the *previous*
        round's churn (the signal to shrink the next budget).

        Pressure is measured against tokens actually *written* to the cache,
        not against block reservations: admission already reserves each
        prompt, so reserved-but-uncomputed space is precisely what scheduled
        prefill tokens consume — counting it as used would throttle chunk
        budgets exactly when there is nothing to protect.

        With the prefix cache, shared pages are counted once however many
        owners reference them (each frozen live page holds exactly
        ``page_size`` written tokens; an owner's private remainder is its
        resident length minus its frozen prefix), and refcount-0 cached
        pages count as *reclaimable* free space — live pressure must not
        back budgets off just because the reclaimable cache is warm."""
        capacity = self.alloc.num_blocks * self.page_size
        ps = self.page_size
        computed = ps * self.alloc.referenced_committed_blocks() + sum(
            max(self._length.get(rid, 0) - ps * self.alloc.committed_count(rid),
                0)
            for rid in self.alloc.owners)
        return KVPressure(utilization=computed / capacity,
                          free_tokens=capacity - computed,
                          reclaimable_tokens=self.alloc.cached_blocks * ps,
                          evictions=self._last_round_evictions)

    # ---- prefix-cache plumbing ----------------------------------------------
    def _content_upto(self, rid: int, upto: int) -> np.ndarray:
        """Token content of ``rid``'s first ``upto`` cache positions: the
        (possibly eviction-grown) prompt, then emitted tokens from the
        folded offset on — exactly what the dispatched writes put there."""
        prompt = self._prompts[rid]
        if upto <= len(prompt):
            return prompt[:upto]
        gen = self._tokens_out.get(rid, [])
        folded = self._folded.get(rid, 0)
        tail = np.asarray(gen[folded:folded + upto - len(prompt)], np.int32)
        return np.concatenate([prompt, tail])

    def _commit(self, rid: int, upto: Optional[int] = None) -> None:
        """Freeze ``rid``'s fully-written pages into the content index (a
        no-op until the resident length crosses the next page boundary).
        Called only after the covering writes were dispatched: any future
        reader matches the pages in a *later* dispatch, so device-order
        guarantees it sees the written content. ``upto`` caps the freeze
        below the resident length — speculative verify rows write k
        unconfirmed draft positions that must never freeze (a rejected tail
        is overwritten next round, and frozen pages may already be shared)."""
        if not self.prefix_cache or rid not in self.alloc.owners:
            return
        if upto is None:
            upto = self._length.get(rid, 0)
        if (upto // self.page_size > self.alloc.committed_count(rid)
                and not self.alloc.commit_stalled(rid)):
            self.alloc.commit(rid, self._content_upto(rid, upto), upto)

    def cache_info(self) -> Dict:
        """Prefix-cache hit/commit accounting (BENCH_goodput.json record)."""
        st = self.stats
        info = {"prefix_cache": self.prefix_cache,
                "hit_tokens": st.cache_hit_tokens,
                "prompt_tokens": st.prompt_tokens,
                "hit_rate": st.cache_hit_tokens / max(st.prompt_tokens, 1),
                "prefill_tokens_computed": st.prefill_tokens}
        if self.cache_mode == "paged":
            info.update(self.alloc.cache_stats())
        return info

    # ---- speculative decoding plumbing --------------------------------------
    def _transcript(self, rid: int) -> np.ndarray:
        """Full visible token history (prompt incl. eviction folds + emitted
        tail) — the drafter's lookup corpus. Host-visible only post-flush,
        which is why speculative rounds flush before assembly."""
        gen = self._tokens_out.get(rid, [])
        tail = gen[self._folded.get(rid, 0):]
        if not tail:
            return self._prompts[rid]
        return np.concatenate([self._prompts[rid],
                               np.asarray(tail, np.int32)])

    def _spec_pressure(self) -> bool:
        """Should latency-critical classes stop speculating? Mirrors the
        scheduler's budget-backoff signals: KV churn or near-full pool."""
        if self._last_round_evictions > 0:
            return True
        backoff = getattr(self.sched, "kv_backoff_util", 0.92)
        return self._kv_pressure().utilization > backoff

    def _propose_drafts(self, r: Request,
                        pressure: bool) -> Optional[np.ndarray]:
        """Draft tokens for one decode-eligible row, after policy caps:
        per-class ``spec_k`` budget, the request's remaining output budget
        (drafting past max_output is wasted verify compute), and the
        interactive-under-pressure opt-out. None -> plain decode row."""
        k = self.spec_class_caps.get(r.class_rank(), self.spec_k)
        k = min(k, self.spec_k, r.max_output - r.generated - 1)
        if k <= 0 or (pressure and r.class_rank() == 0):
            return None
        drafts = self.drafter.propose(self._transcript(r.rid), k)
        if drafts is None or len(drafts) == 0:
            return None
        return np.asarray(drafts[:k], np.int32)

    def _note_spec_accept(self, a: int) -> None:
        """EMA mean/second-moment of per-row accepted length; the std feeds
        the scheduler's TBT-risk shrink (forwarder ``spec_len_std``)."""
        beta = 0.9
        if self.stats.spec_rows <= 1:
            self._spec_acc_mean, self._spec_acc_m2 = float(a), float(a * a)
        else:
            self._spec_acc_mean = (beta * self._spec_acc_mean
                                   + (1 - beta) * a)
            self._spec_acc_m2 = beta * self._spec_acc_m2 + (1 - beta) * a * a

    def _feed_spec_signals(self, round_drafts: int, round_rows: int) -> None:
        """Publish speculation price signals to the scheduler's forwarder:
        expected drafts riding each decode row (what ``to_batch`` prices)
        and the accepted-length std (what the chunker treats as TBT risk)."""
        if round_rows <= 0:
            return
        beta = 0.8
        per_row = round_drafts / round_rows
        self._spec_draft_ema = (per_row if self.stats.spec_rounds <= 1
                                else beta * self._spec_draft_ema
                                + (1 - beta) * per_row)
        F = getattr(self.sched, "F", None)
        if F is not None and hasattr(F, "spec_draft_tokens"):
            F.spec_draft_tokens = self._spec_draft_ema
            var = max(self._spec_acc_m2 - self._spec_acc_mean ** 2, 0.0)
            F.spec_len_std = var ** 0.5

    def spec_info(self) -> Dict:
        """Speculation accounting (BENCH_goodput.json / CI smoke record)."""
        st = self.stats
        return {
            "spec_k": self.spec_k,
            "spec_rounds": st.spec_rounds,
            "verify_rows": st.spec_rows,
            "draft_tokens": st.spec_drafts,
            "accepted_tokens": st.spec_accepted,
            "acceptance_rate": st.spec_accepted / max(st.spec_drafts, 1),
            "emitted_tokens": st.spec_emitted,
            "tokens_per_verify_row": st.spec_emitted / max(st.spec_rows, 1),
            "decode_tokens_per_round": st.decode_tokens / max(st.iterations, 1),
        }

    def _evict(self, victim: Request) -> None:
        """Relegate ``victim`` (recompute-on-resume): drop its pages and fold
        already-emitted tokens into its prompt so re-prefill reconstructs the
        exact cache state and greedy decoding continues deterministically."""
        # folding reads the victim's emitted token *values*; if the previous
        # round is still in flight its ids are not host-visible yet — sync
        # early (this round's one readback just happens now instead of at
        # dispatch time; eviction is the rare path).
        self._flush_round()
        if victim.rid not in self.alloc.owners:
            return      # the flush just finished it (stop token) — no victim
        prompts = self._prompts
        self.alloc.evict(victim.rid)
        self.stats.evictions += 1
        self._bump(self.stats.evicted_by_class, victim.slo_class)
        self._event(EventKind.EVICTED, victim.rid, self._now())
        gen = self._tokens_out.get(victim.rid, [])
        if victim.generated > 0:
            # cache held prompt + gen[:-1] (the newest token was emitted but
            # not yet written back); that is exactly what re-prefill must
            # rebuild. The final emitted token stays pending as the next
            # decode input, so completion of the re-prefill must NOT emit.
            # ``_folded`` guards repeat evictions: tokens already folded into
            # the prompt by an earlier eviction must not be appended twice.
            folded = self._folded.get(victim.rid, 0)
            rebuild = np.asarray(gen[folded:victim.generated - 1], np.int32)
            if len(rebuild):
                prompts[victim.rid] = np.concatenate(
                    [prompts[victim.rid], rebuild])
                victim.prompt_len += len(rebuild)
            self._folded[victim.rid] = victim.generated - 1
            victim.recomputed = victim.generated - 1
            self._resumed.add(victim.rid)
        victim.prefilled = 0
        victim.cached_prefix = 0      # re-matched (if at all) at re-admission
        victim.state = ReqState.WAITING
        self._length.pop(victim.rid, None)
        if victim in self._active:
            self._active.remove(victim)
        self._queued.append(victim)

    def _grow_or_evict(self, req: Request, new_tokens: int,
                       protected: set) -> bool:
        """Grow ``req``'s allocation, evicting lowest-priority owners until
        it fits: prefer requests outside the current decision, then the least
        latency-critical SLO class, then the newest arrival. A victim of a
        *more* critical class than the needy request is never eligible —
        ``batch`` growth can never evict ``interactive`` (the starved request
        simply retries next round, after the critical owners finish).
        Returns False if capacity is exhausted even after evicting every
        eligible owner."""
        by_rid = {r.rid: r for r in self._active}
        needy_rank = req.class_rank()

        def rank_of(rid: int) -> int:
            r = by_rid.get(rid)
            return r.class_rank() if r is not None else needy_rank

        while not self.alloc.grow(req.rid, new_tokens):
            vid = self.alloc.pick_victim(
                req.rid,
                priority=lambda rid: (rid not in protected, rank_of(rid),
                                      by_rid[rid].arrival if rid in by_rid else 0.0),
                eligible=lambda rid: rank_of(rid) >= needy_rank)
            if vid is None or vid not in by_rid:
                return False
            self._evict(by_rid.pop(vid))
        return True

    # ---- zero-sync plumbing --------------------------------------------------
    def _readback(self, arr) -> np.ndarray:
        """The single device→host transfer point of the paged hot path (the
        transfer-counting test pins every other code path behind a
        ``transfer_guard``)."""
        self.stats.token_readbacks += 1
        with jax.transfer_guard_device_to_host("allow"):
            return np.asarray(arr)

    def _flush_round(self) -> None:
        """Materialize the in-flight round: one token-id readback, then append
        emitted ids to ``_tokens_out``, correct provisional timestamps to
        completion time, emit token events, decide stop-token termination
        (the ids are host-visible only here — EOS detection costs no extra
        sync), and feed the scheduler's observe()."""
        fr = self._inflight
        if fr is None:
            return
        self._inflight = None
        t0 = time.perf_counter()
        joined = fr.toks[0] if len(fr.toks) == 1 else jnp.concatenate(fr.toks)
        if self.overlap:
            vals = self._readback(joined)
            toks = {idx: int(vals[idx]) for _, idx in fr.emits}
        else:
            # legacy profile: one scalar transfer per emitting row, like the
            # pre-zero-sync engine's per-row ``int(jnp.argmax(logits[i]))``
            # (verify-row spans transfer per row too in this mode).
            toks = {idx: int(self._readback(joined[idx]))
                    for _, idx in fr.emits}
            if fr.spec_emits:
                vals = self._readback(joined)
        self.stats.sync_s += time.perf_counter() - t0
        t_done = self._now()
        by_rid = {r.rid: (r, k, wf, fin) for r, k, wf, fin in fr.stamped}
        for rid, idx in fr.emits:
            tok = toks[idx]
            self._tokens_out.setdefault(rid, []).append(tok)
            entry = by_rid.get(rid)
            if entry is None:
                continue
            r, k, was_first, was_finish = entry
            r.token_times[k] = t_done
            if was_first:
                r.first_token_time = t_done
            self._event(EventKind.FIRST_TOKEN if was_first
                        else EventKind.TOKEN, rid, t_done, token=tok)
            if was_finish:
                r.finish_time = t_done
                self._event(EventKind.FINISHED, rid, t_done, reason="length")
            elif r.state == ReqState.DECODING and r.hits_stop(tok):
                # stop-token termination, decided from the deferred readback.
                # The request may already sit in the next round's assembled
                # batch; that row executes dead (trash write, no emit).
                r.state = ReqState.FINISHED
                r.finish_time = t_done
                self._retire(r)
                self._done.append(r)
                self._bump(self.stats.finished_by_class, r.slo_class)
                self._event(EventKind.FINISHED, rid, t_done, reason="stop")
        for rid, base, Lb, n_real, start in fr.spec_emits:
            # payload span: [accepted, out_0 .. out_{Lb-1}]. The emitted
            # stream is out_0..out_a (a accepted drafts + the bonus token) —
            # exact autoregressive output, so greedy tokens are bit-identical
            # to plain decode at any k. Rejected tail KV sits in positions
            # start+m .. start+n_real-1 of already-owned pages; rolling the
            # resident length back makes the next round overwrite it.
            a = min(int(vals[base]), n_real - 1)
            outs = [int(v) for v in vals[base + 1:base + 2 + a]]
            r = self._reqs.get(rid)
            self.stats.spec_rows += 1
            self.stats.spec_drafts += n_real - 1
            self._note_spec_accept(a)
            if r is None or r.state in (ReqState.FINISHED, ReqState.ABORTED):
                continue        # aborted between dispatch and flush
            m = 0
            finished_reason = ""
            for tok in outs:
                self._tokens_out.setdefault(rid, []).append(tok)
                m += 1
                r.emit_token(t_done)
                self.stats.decode_tokens += 1
                self._event(EventKind.TOKEN, rid, t_done, token=tok)
                if r.state == ReqState.FINISHED:        # max_output reached
                    finished_reason = "length"
                    break
                if r.hits_stop(tok):
                    r.state = ReqState.FINISHED
                    finished_reason = "stop"
                    break
            self.stats.spec_accepted += a
            self.stats.spec_emitted += m
            if rid in self._length:
                self._length[rid] = start + m
            if finished_reason:
                r.finish_time = t_done
                self._retire(r)
                self._done.append(r)
                self._bump(self.stats.finished_by_class, r.slo_class)
                self._event(EventKind.FINISHED, rid, t_done,
                            reason=finished_reason)
        latency = time.perf_counter() - fr.t_dispatch
        # dispatch->flush intervals are disjoint (the next dispatch happens
        # only after this flush), so their sum is the wall time covered by an
        # in-flight round; the remainder is unhidden host overhead.
        self.stats.device_busy_s += latency
        self.sched.observe(fr.executed_batch, latency, kv=self._kv_pressure())

    def _upload_cached(self, kind, arr: np.ndarray) -> jnp.ndarray:
        """Host→device upload with content reuse: block tables are stable
        across steady decode rounds (they only change when a request crosses
        a page boundary or the batch recomposes), so the device buffer from
        the previous round is reused instead of re-uploaded. Keyed per
        consumer ``kind`` and per row-group, so the multiple same-shape
        dispatches of a split oversized round don't evict each other's
        entries within one round. The KV pool layout tag is part of the key:
        a stale buffer uploaded against a different physical page layout
        must never be reused (same table contents index different bytes)."""
        key = (kind, arr.shape, PAGED_KV_LAYOUT)
        prev = self._dev_cache.get(key)
        if prev is not None and np.array_equal(prev[0], arr):
            self.stats.reused_uploads += 1
            return prev[1]
        dev = self._to_dev(arr)
        self._dev_cache[key] = (arr, dev)
        return dev

    # ---- fused dispatch assembly ---------------------------------------------
    def _page_slots(self, rid: int, positions: np.ndarray) -> np.ndarray:
        pt = np.asarray(self.alloc.page_table(rid), np.int64)
        return pt[positions // self.page_size] * self.page_size \
            + positions % self.page_size

    def lengths_of(self, req: Request) -> int:
        return self._length.get(req.rid, 0)

    def _assemble_chunk(self, batch: List[Tuple[Request, int, int]],
                        prompts: Dict[int, np.ndarray]) -> dict:
        """Numpy assembly of one fused ragged-prefill dispatch (pure host
        work; runs while the previous round executes on device)."""
        R = len(batch)
        Rb = _row_bucket(R)
        Lb = _bucket(max(n for _, _, n in batch))
        nb = _pow2(max(self.alloc.blocks_for(s + n) for _, s, n in batch))
        tokens = np.zeros((Rb, Lb), np.int32)
        row_pos = np.zeros((Rb,), np.int32)
        row_lens = np.zeros((Rb,), np.int32)
        logits_at = np.zeros((Rb,), np.int32)
        tables = np.zeros((Rb, nb), np.int32)
        slots = np.full((Rb, Lb), self._trash_slot, np.int64)
        emit_rows: List[Tuple[int, int]] = []
        for i, (r, start, n) in enumerate(batch):
            tokens[i, :n] = prompts[r.rid][start:start + n]
            row_pos[i] = start
            row_lens[i] = start + n
            logits_at[i] = n - 1
            # the owner may hold pages beyond this dispatch's read range (a
            # split oversized chunk grows the whole allocation up front);
            # only the prefix covering start+n tokens belongs in the table.
            need = self.alloc.blocks_for(start + n)
            tables[i, :need] = self.alloc.page_table(r.rid)[:need]
            slots[i, :n] = self._page_slots(r.rid, np.arange(start, start + n))
            self._length[r.rid] = start + n
            if start + n >= r.prompt_len and r.rid not in self._resumed:
                emit_rows.append((r.rid, i))
        return {"kind": "chunk", "tokens": tokens, "row_pos": row_pos,
                "row_lens": row_lens, "logits_at": logits_at,
                "tables": tables, "slots": slots, "emit_rows": emit_rows,
                "Rb": Rb, "Lb": Lb, "nb": nb}

    def _assemble_prefill(self, entries: List[Tuple[Request, int]],
                          prompts: Dict[int, np.ndarray]) -> List[dict]:
        """Split the decision's prefill rows over the chunk-length and row
        ladders: rows above the top chunk bucket loop over extra dispatches,
        row counts above the top row bucket split across dispatches."""
        asms: List[dict] = []
        work = [[r, self.lengths_of(r), n] for r, n in entries]
        while work:
            step_batch = [(r, s, min(n, CHUNK_BUCKETS[-1])) for r, s, n in work]
            for i in range(0, len(step_batch), ROW_BUCKETS[-1]):
                asm = self._assemble_chunk(
                    step_batch[i:i + ROW_BUCKETS[-1]], prompts)
                asm["group"] = len(asms)
                asms.append(asm)
            nxt = []
            for (r, s, n), (_, _, step) in zip(work, step_batch):
                if n - step > 0:
                    nxt.append([r, s + step, n - step])
            work = nxt
        return asms

    def _assemble_decode(self, reqs: Sequence[Request]) -> dict:
        """Numpy assembly of one fused decode dispatch; the input token ids
        are filled in after the previous round's flush (they are its output)."""
        R = len(reqs)
        Rb = _row_bucket(R)
        new_lens = [self._length[r.rid] + 1 for r in reqs]
        pts = [self.alloc.page_table(r.rid) for r in reqs]
        # decode rows carry their *full* reserved page table (it only changes
        # on grow/evict, never on a per-token page-boundary crossing), so the
        # uploaded table bytes are stable round over round and the device
        # buffer cache actually hits; pages past ceil(len/ps) are never read
        # (the kernel skips them, the oracle masks them).
        nb = _pow2(max(len(pt) for pt in pts))
        tokens = np.zeros((Rb, 1), np.int32)
        lengths = np.zeros((Rb,), np.int32)
        tables = np.zeros((Rb, nb), np.int32)
        slots = np.full((Rb,), self._trash_slot, np.int64)
        for i, (r, pt) in enumerate(zip(reqs, pts)):
            lengths[i] = new_lens[i]
            tables[i, :len(pt)] = pt
            slots[i] = self._page_slots(
                r.rid, np.asarray([new_lens[i] - 1]))[0]
            self._length[r.rid] += 1
        return {"kind": "decode", "rids": [r.rid for r in reqs],
                "tokens": tokens, "lengths": lengths, "tables": tables,
                "slots": slots, "Rb": Rb, "nb": nb}

    def _assemble_spec(self, batch: List[Tuple[Request, np.ndarray]]) -> dict:
        """Numpy assembly of one fused speculative-verify dispatch: each row
        is [pending token, draft_1..draft_k] at the request's resident
        offset, executed through the ragged paged-prefill step. Runs *after*
        the flush (unlike plain decode assembly) because the pending token
        and the write positions depend on the previous round's accepted
        counts. The resident length is advanced optimistically over the
        whole row and rolled back to ``start + emitted`` at the flush."""
        R = len(batch)
        Rb = _row_bucket(R)
        max_n = max(1 + len(d) for _, d in batch)
        # verify rows are narrow (k+1 tokens); the chunk ladder's 16-wide
        # floor would waste 4x the verify compute, so they get their own
        # power-of-two width starting at 2.
        Lb = _pow2(max_n, lo=2)
        pts = [self.alloc.page_table(r.rid) for r, _ in batch]
        # full reserved page table, like decode rows: stable bytes round over
        # round so the device table-upload cache hits.
        nb = _pow2(max(len(pt) for pt in pts))
        tokens = np.zeros((Rb, Lb), np.int32)
        row_pos = np.zeros((Rb,), np.int32)
        row_lens = np.zeros((Rb,), np.int32)
        tables = np.zeros((Rb, nb), np.int32)
        slots = np.full((Rb, Lb), self._trash_slot, np.int64)
        rows: List[Tuple[int, int, int]] = []   # (rid, n_real, start)
        for i, ((r, drafts), pt) in enumerate(zip(batch, pts)):
            rid = r.rid
            start = self._length[rid]
            n = 1 + len(drafts)
            tokens[i, 0] = self._tokens_out[rid][-1]
            tokens[i, 1:n] = drafts
            if n < Lb:
                tokens[i, n:] = tokens[i, n - 1]   # pad; writes hit trash
            row_pos[i] = start
            row_lens[i] = start + n
            tables[i, :len(pt)] = pt
            slots[i, :n] = self._page_slots(rid, np.arange(start, start + n))
            self._length[rid] = start + n
            rows.append((rid, n, start))
        return {"kind": "spec", "tokens": tokens, "row_pos": row_pos,
                "row_lens": row_lens, "tables": tables, "slots": slots,
                "rows": rows, "Rb": Rb, "Lb": Lb, "nb": nb}

    def _dispatch(self, asm: dict):
        """Issue one fused dispatch (async under JAX dispatch); returns the
        device token-id vector — [Rb] for decode/chunk, [Rb*(Lb+1)] payload
        for spec. The RNG nonce advances per dispatch so sampled rounds stay
        reproducible (the sequence of dispatches is deterministic)."""
        nonce = self._to_dev(np.int32(self._sample_nonce))
        self._sample_nonce += 1
        if asm["kind"] == "decode":
            self._note_shape(("decode", asm["Rb"], asm["nb"]))
            toks, self.cache = self._jit_decode_fused(
                self.params, self._to_dev(asm["tokens"]), self.cache,
                self._to_dev(asm["lengths"]),
                self._upload_cached(("decode", asm.get("group", 0)),
                                    asm["tables"]),
                self._to_dev(asm["slots"].astype(np.int32)), nonce)
            self.stats.decode_calls += 1
        elif asm["kind"] == "spec":
            self._note_shape(("spec", asm["Rb"], asm["Lb"], asm["nb"]))
            toks, self.cache = self._jit_spec_fused(
                self.params, self._to_dev(asm["tokens"]), self.cache,
                self._to_dev(asm["row_pos"]), self._to_dev(asm["row_lens"]),
                self._upload_cached(("spec", asm.get("group", 0)),
                                    asm["tables"]),
                self._to_dev(asm["slots"].reshape(-1).astype(np.int32)),
                nonce)
            self.stats.spec_calls += 1
        else:
            self._note_shape(("chunk", asm["Rb"], asm["Lb"], asm["nb"]))
            toks, self.cache = self._jit_chunk_fused(
                self.params, self._to_dev(asm["tokens"]), self.cache,
                self._to_dev(asm["row_pos"]), self._to_dev(asm["row_lens"]),
                self._upload_cached(("chunk", asm.get("group", 0)),
                                    asm["tables"]),
                self._to_dev(asm["slots"].reshape(-1).astype(np.int32)),
                self._to_dev(asm["logits_at"]), nonce)
            self.stats.prefill_calls += 1
        self._round_calls += 1
        return toks

    def _note_shape(self, key) -> None:
        if key not in self._seen_shapes:
            self._seen_shapes.add(key)
            self.stats.compiled_shapes += 1

    # =========================================================================
    # offline compatibility wrapper (shared by both cache modes)
    # =========================================================================
    def serve(self, requests: Sequence[Request],
              prompts: Optional[Dict[int, np.ndarray]] = None,
              max_wall_s: float = 300.0) -> Dict:
        """Serve a complete request list (arrival times are wall-clock
        offsets from this call) and block until everything finishes.

        Thin wrapper over the step API: resets the engine clock, feeds every
        request through ``add_request``, and drives ``step()`` — sleeping
        between arrivals and yielding briefly on empty rounds, exactly as the
        pre-step monolithic loop did. Greedy tokens and the
        one-readback-per-round count are identical to driving ``step()``
        directly. The engine must be drained — resetting the clock under
        live requests would corrupt their arrival-relative deadlines."""
        assert not self.has_work(), \
            "serve() on an engine with live requests (drain or use step())"
        rng = np.random.default_rng(0)
        prompts = prompts or {
            r.rid: rng.integers(0, self.cfg.vocab_size, r.prompt_len).astype(np.int32)
            for r in requests
        }
        self._t0 = time.perf_counter()
        busy0 = self.stats.device_busy_s    # stats accumulate across serve()s
        done0 = len(self._done)
        for r in sorted(requests, key=lambda r: r.arrival):
            self.add_request(r, prompts[r.rid])

        empty_rounds = 0
        while self.has_work() and self._now() < max_wall_s:
            self.step()
            if self._progress == "executed":
                empty_rounds = 0
            elif self._progress == "empty":
                # every entry was evicted away (severe KV pressure): yield so
                # re-admission can make progress — but if no eviction changed
                # any state either, the engine is wedged (e.g. a lone request
                # outgrew total capacity); bail instead of spinning to the
                # wall clock.
                empty_rounds += 1
                if self._last_round_evictions == 0 and empty_rounds >= 8:
                    break
                time.sleep(1e-3)
            elif self._progress == "no-decision":
                time.sleep(1e-3)
            else:   # idle: nothing admitted/admissible (in-flight is flushed)
                if self._pending:
                    time.sleep(max(self._pending[0][0] - self._now(), 0.0)
                               + 1e-4)
                elif self._queued:   # arrived but nothing fits: wedged
                    break

        # the final flush's events have no step() caller to collect them;
        # drop them so a later driver of this engine doesn't receive stale
        # TOKEN/FINISHED events for long-gone requests.
        self.flush()
        wall = self._now()
        # host_s is per-serve (this call's wall minus this call's in-flight
        # coverage); the other counters are cumulative across serve() calls.
        self.stats.host_s = max(
            wall - (self.stats.device_busy_s - busy0), 0.0)
        return {
            "finished": self._done[done0:],
            "unfinished": [r for r in requests if r.state != ReqState.FINISHED],
            "stats": self.stats,
            "outputs": dict(self._tokens_out),
            "wall": wall,
        }

    # ---- per-mode decision execution -----------------------------------------
    def _execute_slot(self, decision) -> List[Tuple[Request, int, int]]:
        executed = []
        decode_reqs = [r for r, n in decision.alloc
                       if r.state == ReqState.DECODING]
        if decode_reqs:
            self._run_decode_slot(decode_reqs)
            executed += [(r, 1, r.context_len()) for r in decode_reqs]
        for r, n in decision.alloc:
            if r.state != ReqState.DECODING:
                ctx = r.context_len()
                n_exec = self._run_prefill_chunk(r, n, self._prompts[r.rid])
                if n_exec > 0:
                    executed.append((r, n_exec, ctx))
        return executed

    def _execute_paged(self, decision) -> List[Tuple]:
        """Grow allocations (evicting under pressure), assemble the round on
        the host while the previous round still runs on device, sync once on
        the previous round's token ids, then dispatch the decision as fused
        decode + speculative-verify + ragged prefill batches (all async).

        With ``spec_k > 0`` the round's one readback moves *before* assembly
        instead of after: round N's accepted counts decide round N+1's write
        positions (host-side rollback) and drafting needs the newest emitted
        token host-visible. Still exactly one readback per round — only the
        host assembly loses its overlap with the device. At ``spec_k == 0``
        the original assemble-then-flush order is untouched."""
        prompts = self._prompts
        protected = {r.rid for r, _ in decision.alloc}
        ev0 = self.alloc.evictions
        spec_on = self.spec_k > 0 and self.drafter is not None
        if spec_on:
            self._flush_round()
        self._round_spec_rids = set()

        def is_live(r):  # an earlier grow may have evicted a later entry
            return r.rid in self.alloc.owners and r.state not in (
                ReqState.FINISHED, ReqState.ABORTED)

        pressure = self._spec_pressure() if spec_on else False
        decode_rows: List[Request] = []
        spec_rows: List[Tuple[Request, np.ndarray]] = []
        prefill_rows: List[Tuple[Request, int]] = []
        for r, n in decision.alloc:
            if not is_live(r):
                continue
            if r.state == ReqState.DECODING:
                drafts = self._propose_drafts(r, pressure) if spec_on else None
                if drafts is not None and self._grow_or_evict(
                        r, self._length[r.rid] + 1 + len(drafts), protected):
                    spec_rows.append((r, drafts))
                elif self._grow_or_evict(r, self._length[r.rid] + 1,
                                         protected):
                    decode_rows.append(r)
            else:
                n_exec = min(n, r.remaining_prefill())
                if n_exec <= 0:
                    continue
                start = self._length.get(r.rid, 0)
                # admission reserved the full remaining prompt, so this grow
                # is a no-op today; it stays so a future partial-reservation
                # admission policy still allocates (or skips) correctly.
                if not self._grow_or_evict(r, start + n_exec, protected):
                    continue
                prefill_rows.append((r, n_exec))
        decode_rows = [r for r in decode_rows if is_live(r)]
        spec_rows = [(r, d) for r, d in spec_rows if is_live(r)]
        prefill_rows = [(r, n) for r, n in prefill_rows if is_live(r)]
        self._last_round_evictions = self.alloc.evictions - ev0
        if not decode_rows and not spec_rows and not prefill_rows:
            return []

        # ---- host-side numpy assembly (device still busy with round N) ------
        executed: List[Tuple] = []
        decode_asms: List[dict] = []
        if decode_rows:
            ctxs = {r.rid: r.context_len() for r in decode_rows}
            for i in range(0, len(decode_rows), ROW_BUCKETS[-1]):
                asm = self._assemble_decode(decode_rows[i:i + ROW_BUCKETS[-1]])
                asm["group"] = i // ROW_BUCKETS[-1]
                decode_asms.append(asm)
            executed += [(r, 1, ctxs[r.rid]) for r in decode_rows]
        spec_asms: List[dict] = []
        if spec_rows:
            self.stats.spec_rounds += 1
            for i in range(0, len(spec_rows), ROW_BUCKETS[-1]):
                asm = self._assemble_spec(spec_rows[i:i + ROW_BUCKETS[-1]])
                asm["group"] = i // ROW_BUCKETS[-1]
                spec_asms.append(asm)
            for r, drafts in spec_rows:
                self._round_spec_rids.add(r.rid)
                executed.append((r, 1 + len(drafts), r.context_len(),
                                 len(drafts)))
        if spec_on:
            self._feed_spec_signals(
                sum(len(d) for _, d in spec_rows),
                len(decode_rows) + len(spec_rows))
        chunk_asms: List[dict] = []
        if prefill_rows:
            ctxs = {r.rid: r.context_len() for r, _ in prefill_rows}
            chunk_asms = self._assemble_prefill(prefill_rows, prompts)
            executed += [(r, n, ctxs[r.rid]) for r, n in prefill_rows]
            for r, n in prefill_rows:
                self.stats.prefill_tokens += n
                self._commit(r.rid)   # freeze pages this round fills

        # ---- the round's single sync: round N's token ids -------------------
        # (no-op when spec_on already flushed above — never a second sync)
        self._flush_round()

        # ---- dispatch round N+1 (async) -------------------------------------
        t_disp = time.perf_counter()
        toks, emits, spec_emits, off = [], [], [], 0
        for asm in decode_asms:
            # decode inputs are round N's outputs — only now host-visible
            for i, rid in enumerate(asm["rids"]):
                r = self._reqs.get(rid)
                if r is None or r.state in (ReqState.FINISHED,
                                            ReqState.ABORTED):
                    # the flush above finished this request (stop token): its
                    # row was assembled before the ids were host-visible —
                    # execute it dead (KV write to the trash page, no emit).
                    asm["slots"][i] = self._trash_slot
                    continue
                prev = self._tokens_out.get(rid)
                asm["tokens"][i, 0] = prev[-1] if prev else 0
                emits.append((rid, off + i))
                # the write slot this row fills (position _length-1) may have
                # completed a page; its content — prompt + emitted ids — is
                # host-known now, so decode pages freeze too (multi-turn
                # follow-ups match across generated output).
                self._commit(rid)
            toks.append(self._dispatch(asm))
            off += asm["Rb"]
        for asm in spec_asms:
            toks.append(self._dispatch(asm))
            W = asm["Lb"] + 1
            for i, (rid, n_real, start) in enumerate(asm["rows"]):
                spec_emits.append((rid, off + i * W, asm["Lb"], n_real,
                                   start))
                # only the pending token's position (start) is confirmed
                # content — draft positions must not freeze until accepted.
                self._commit(rid, upto=start + 1)
            off += asm["Rb"] * W
        for asm in chunk_asms:
            toks.append(self._dispatch(asm))
            emits += [(rid, off + row) for rid, row in asm["emit_rows"]
                      if rid in self._reqs]
            off += asm["Rb"]
        self.stats.dispatch_s += time.perf_counter() - t_disp
        self._inflight = _InflightRound(toks=toks, emits=emits,
                                        t_dispatch=t_disp,
                                        spec_emits=spec_emits)
        return executed


# Back-compat name: the engine core was born as the monolithic ServingEngine;
# existing callers (tests, benchmarks) keep the old import path.
ServingEngine = EngineCore
