"""Synthetic workloads matched to the paper's Table 2 statistics.

The paper uses ShareGPT (dialogue) and two arXiv-summarization subsets; those
HF datasets are not available offline, so we synthesize length distributions
whose (mean, P90) match Table 2 exactly:

    dataset     prompt mean/P90     output mean/P90     SLO class
    sharegpt      357 / 1724          89 / 184          dialogue
    arxiv-v1     3253 / 4382         356 / 542          summarization
    arxiv-v2     6267 / 7567         423 / 623          summarization
    mixed-v1     sharegpt : arxiv-v1 = 3 : 1
    mixed-v2     sharegpt : arxiv-v2 = 5 : 1

Lognormal when a single lognormal can hit both moments; otherwise (ShareGPT
prompts, whose P90/mean ratio exceeds any lognormal's) a two-component
lognormal mixture fit by moment matching. SLOs follow Table 3: TTFT is a max
*slowdown* over exclusive service, TBT a fixed per-token bound.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.costmodel import CostModel
from repro.serving.request import Request

Z90 = 1.2815515655446004

TABLE2 = {
    "sharegpt": {"prompt": (357, 1724), "output": (89, 184), "slo": "dialogue"},
    "arxiv-v1": {"prompt": (3253, 4382), "output": (356, 542), "slo": "summarization"},
    "arxiv-v2": {"prompt": (6267, 7567), "output": (423, 623), "slo": "summarization"},
}
MIXES = {
    "mixed-v1": (("sharegpt", 3), ("arxiv-v1", 1)),
    "mixed-v2": (("sharegpt", 5), ("arxiv-v2", 1)),
}
# Table 3.
SLOS = {
    "dialogue": {"ttft_slowdown": 5.0, "tbt": 0.040},
    "summarization": {"ttft_slowdown": 10.0, "tbt": 0.080},
}
DATASETS = tuple(TABLE2) + tuple(MIXES)


def _lognormal_params(mean: float, p90: float) -> Optional[Tuple[float, float]]:
    """(mu, sigma) matching mean & p90, or None if infeasible."""
    L = math.log(mean / p90)
    disc = Z90 * Z90 + 2 * L
    if disc < 0:
        return None
    sigma = Z90 - math.sqrt(disc)
    mu = math.log(mean) - sigma * sigma / 2
    return mu, sigma


def _mixture_params(mean: float, p90: float,
                    sigma_s: float = 0.55, sigma_l: float = 0.35):
    """Two-lognormal mixture: a short body + a long tail near/above P90.

    Solved by scanning the tail weight q and tail location; short-component
    mean follows from the total-mean constraint; q is picked so the P90
    matches (tail mass just under 10% puts P90 at the tail's lower edge).
    """
    best = None
    for q in np.linspace(0.02, 0.20, 37):
        for m_l in np.linspace(p90, 4 * p90, 25):
            m_s = (mean - q * m_l) / (1 - q)
            if m_s <= 1:
                continue
            mu_s = math.log(m_s) - sigma_s ** 2 / 2
            mu_l = math.log(m_l) - sigma_l ** 2 / 2
            # numeric P90 of the mixture
            xs = np.exp(np.linspace(math.log(4), math.log(30 * p90), 512))
            from math import erf, sqrt
            cdf = (1 - q) * 0.5 * (1 + np.vectorize(erf)((np.log(xs) - mu_s) / (sigma_s * sqrt(2)))) \
                + q * 0.5 * (1 + np.vectorize(erf)((np.log(xs) - mu_l) / (sigma_l * sqrt(2))))
            p90_hat = float(np.interp(0.9, cdf, xs))
            err = abs(p90_hat - p90) / p90
            if best is None or err < best[0]:
                best = (err, q, mu_s, sigma_s, mu_l, sigma_l)
    return best[1:]


class LengthSampler:
    def __init__(self, mean: float, p90: float, lo: int = 4, hi: Optional[int] = None):
        self.lo, self.hi = lo, hi or int(20 * p90)
        ln = _lognormal_params(mean, p90)
        if ln is not None:
            self.kind = "lognormal"
            self.params = ln
        else:
            self.kind = "mixture"
            self.params = _mixture_params(mean, p90)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        if self.kind == "lognormal":
            mu, sigma = self.params
            x = rng.lognormal(mu, sigma, n)
        else:
            q, mu_s, sig_s, mu_l, sig_l = self.params
            tail = rng.random(n) < q
            x = np.where(tail, rng.lognormal(mu_l, sig_l, n), rng.lognormal(mu_s, sig_s, n))
        return np.clip(x, self.lo, self.hi).astype(int)


@dataclasses.dataclass
class WorkloadSpec:
    dataset: str
    qps: float
    duration: float
    seed: int = 0


def make_workload(spec: WorkloadSpec, cost_model: CostModel) -> List[Request]:
    """Poisson arrivals with Table-2 lengths and Table-3 SLOs."""
    rng = np.random.default_rng(spec.seed)
    components: List[Tuple[str, float]] = []
    if spec.dataset in TABLE2:
        components = [(spec.dataset, 1.0)]
    elif spec.dataset in MIXES:
        total = sum(w for _, w in MIXES[spec.dataset])
        components = [(name, w / total) for name, w in MIXES[spec.dataset]]
    else:
        raise KeyError(f"unknown dataset {spec.dataset!r}; options: {DATASETS}")

    samplers = {
        name: (LengthSampler(*TABLE2[name]["prompt"]),
               LengthSampler(*TABLE2[name]["output"], lo=1))
        for name, _ in components
    }

    n_est = int(spec.qps * spec.duration * 1.2) + 16
    inter = rng.exponential(1.0 / spec.qps, n_est)
    arrivals = np.cumsum(inter)
    arrivals = arrivals[arrivals < spec.duration]

    names = [c[0] for c in components]
    probs = [c[1] for c in components]
    reqs: List[Request] = []
    for i, a in enumerate(arrivals):
        name = names[int(rng.choice(len(names), p=probs))]
        p_len = int(samplers[name][0].sample(rng, 1)[0])
        o_len = int(samplers[name][1].sample(rng, 1)[0])
        slo = SLOS[TABLE2[name]["slo"]]
        excl = cost_model.exclusive_prefill_time(p_len)
        reqs.append(Request(
            rid=i, arrival=float(a), prompt_len=p_len, max_output=o_len,
            ttft_slo=slo["ttft_slowdown"] * excl, tbt_slo=slo["tbt"],
            slo_class=TABLE2[name]["slo"], exclusive_ttft=excl,
        ))
    return reqs


# ---------------------------------------------------------------------------
# prefix-sharing scenarios (radix prefix cache: shared system prompts and
# multi-turn chat; these need real token ids, so they return prompts too)
# ---------------------------------------------------------------------------
def make_shared_prefix_workload(n: int, vocab_size: int, *,
                                system_len: int = 96, unique_len: int = 32,
                                max_output: int = 6, qps: float = 0.0,
                                slo_class: str = "standard",
                                ttft_slo: float = 60.0, tbt_slo: float = 60.0,
                                seed: int = 0, rid0: int = 0
                                ) -> Tuple[List[Request], Dict[int, np.ndarray]]:
    """The production shared-system-prompt scenario: ``n`` requests whose
    prompts share one ``system_len``-token prefix and differ only in a
    ``unique_len``-token suffix (few-shot templates, RAG headers, agent
    system prompts). With the engine's prefix cache on, every request after
    the first should prefill only its suffix plus the shared prefix's
    partial tail page. ``qps=0`` arrives everything at t=0 (a burst);
    otherwise arrivals are Poisson. Returns ``(requests, prompts)``."""
    rng = np.random.default_rng(seed)
    system = rng.integers(1, vocab_size, system_len).astype(np.int32)
    arrivals = (np.zeros(n) if qps <= 0
                else np.cumsum(rng.exponential(1.0 / qps, n)))
    reqs, prompts = [], {}
    for i in range(n):
        rid = rid0 + i
        suffix = rng.integers(1, vocab_size, unique_len).astype(np.int32)
        prompts[rid] = np.concatenate([system, suffix])
        reqs.append(Request(rid=rid, arrival=float(arrivals[i]),
                            prompt_len=system_len + unique_len,
                            max_output=max_output, ttft_slo=ttft_slo,
                            tbt_slo=tbt_slo, slo_class=slo_class))
    return reqs, prompts


def make_router_workload(vocab_size: int, *, n_shared: int = 10,
                         system_len: int = 96, unique_len: int = 24,
                         shared_output: int = 6, n_batch: int = 4,
                         batch_prompt: int = 120, batch_output: int = 12,
                         heavy_prompt: int = 400, heavy_output: int = 48,
                         gap_s: float = 0.2, seed: int = 0
                         ) -> Tuple[List[Request], Dict[int, np.ndarray]]:
    """The multi-replica routing scenario: a **shared-prefix interactive
    stream** riding next to **background batch work**, shaped so the two
    routing policies separate.

    One heavy batch request arrives first (token mass a count-based router
    cannot compensate for), then ``n_shared`` interactive requests sharing a
    ``system_len``-token prefix arrive at ``gap_s`` spacing (the spacing
    lets the first one commit its pages before the rest route, so the
    directory steers the whole stream to one replica where all but the
    first prefill only their suffix), then ``n_batch`` medium batch
    requests arrive last — free mass a load-aware router places opposite
    the heavy request. Round-robin spreads the shared prefix across
    replicas (each pays its own cold prefill) and stacks the heavy request
    with half the stream regardless of cost; prefix-affine concentrates
    the (cheap, cached) stream on one replica and levels the rest by
    measured load — which is exactly the computed-token imbalance gap
    ``bench_goodput --replicas`` measures. Returns ``(requests, prompts)``."""
    rng = np.random.default_rng(seed)
    system = rng.integers(1, vocab_size, system_len).astype(np.int32)
    reqs: List[Request] = []
    prompts: Dict[int, np.ndarray] = {}
    rid = 0

    def add(prompt: np.ndarray, arrival: float, max_output: int,
            slo_class: str) -> None:
        nonlocal rid
        prompts[rid] = prompt
        reqs.append(Request(rid=rid, arrival=arrival,
                            prompt_len=len(prompt), max_output=max_output,
                            ttft_slo=60.0, tbt_slo=60.0,
                            slo_class=slo_class))
        rid += 1

    add(rng.integers(1, vocab_size, heavy_prompt).astype(np.int32),
        0.0, heavy_output, "batch")
    t = gap_s
    for _ in range(n_shared):
        suffix = rng.integers(1, vocab_size, unique_len).astype(np.int32)
        add(np.concatenate([system, suffix]), t, shared_output,
            "interactive")
        t += gap_s
    for _ in range(n_batch):
        add(rng.integers(1, vocab_size, batch_prompt).astype(np.int32),
            t, batch_output, "batch")
        t += gap_s
    return reqs, prompts


def multiturn_followup(prompt: np.ndarray, output_ids: Sequence[int],
                       rng: np.random.Generator, vocab_size: int,
                       turn_len: int = 24) -> np.ndarray:
    """Next-turn prompt of a chat conversation: the full transcript so far
    (previous prompt + generated reply) plus a fresh ``turn_len``-token user
    turn. Submitted against a warm prefix cache, everything but the new turn
    (and the transcript's partial tail page) should match frozen pages —
    including pages frozen *during decode* of the previous turn."""
    turn = rng.integers(1, vocab_size, turn_len).astype(np.int32)
    return np.concatenate([np.asarray(prompt, np.int32),
                           np.asarray(list(output_ids), np.int32), turn])


# ---------------------------------------------------------------------------
# open-loop live-arrival driver (streaming frontend)
# ---------------------------------------------------------------------------
def run_open_loop(server, requests: Sequence[Request],
                  prompts: Optional[Dict[int, np.ndarray]] = None,
                  max_wall_s: float = 300.0, seed: int = 0) -> Dict:
    """Replay a workload through an :class:`InferenceServer` the way live
    traffic hits a deployment: **open-loop** — each request is submitted at
    its wall-clock ``arrival`` offset regardless of how far the engine has
    gotten (arrivals never wait on completions), and the server is pumped in
    between so admitted work streams continuously.

    This is the live-arrival counterpart of ``EngineCore.serve``: ``serve``
    hands the engine the complete schedule up front (offline replay), while
    this driver only reveals each request when its arrival time passes —
    exactly what the streaming submit API experiences in production.

    SLO clocks run from each request's *scheduled* arrival (``t0 +
    r.arrival`` on the engine clock), not from when this loop got around to
    submitting it — submission delay counts as queueing time, exactly as
    offline ``serve()`` measures it.

    The request objects are **consumed**: their runtime state advances and
    ``arrival`` is rewritten onto the engine clock. Rebuild the workload
    list to replay it (as ``bench_goodput`` does); re-passing the same
    objects would compound the arrival rebase.

    Returns ``{"handles", "finished", "unfinished", "wall", "events"}``;
    per-request tokens are on each handle (``handle.collected``).
    """
    rng = np.random.default_rng(seed)
    vocab = server.core.cfg.vocab_size
    prompts = prompts or {
        r.rid: rng.integers(0, vocab, r.prompt_len).astype(np.int32)
        for r in requests
    }
    order = sorted(requests, key=lambda r: r.arrival)
    t0 = server.core.now()
    n_ev0 = len(server.events)
    handles: Dict[int, object] = {}
    i = 0
    t_end = time.perf_counter() + max_wall_s
    while i < len(order) and time.perf_counter() < t_end:
        now = server.core.now() - t0
        while i < len(order) and order[i].arrival <= now:
            r = order[i]
            r.arrival = t0 + r.arrival   # workload offset -> engine clock
            handles[r.rid] = server.submit_request(r, prompts[r.rid])
            i += 1
        if i == len(order):
            break
        if not server.core.has_work():
            # nothing to run yet: sleep the gap to the next arrival
            time.sleep(max(order[i].arrival - (server.core.now() - t0), 0.0)
                       + 1e-4)
            continue
        server.step()
        if server.core.progress != "executed":
            # bounded yield so the arrival scan stays responsive (unlike
            # server.run(), arrivals here are revealed by *this* loop)
            time.sleep(1e-3)
    # drain: no more arrivals; server.run finishes what the engine holds
    # (its stall guard stops a wedged queue from spinning to the wall clock)
    server.run(max_wall_s=max(t_end - time.perf_counter(), 0.0))
    finished = [h for h in handles.values()
                if h.finished and not h.aborted]
    return {
        "handles": handles,
        "finished": finished,
        "unfinished": [h for h in handles.values() if not h.finished],
        "wall": server.core.now() - t0,
        "events": server.events[n_ev0:],
    }


def run_open_loop_http(client, requests: Sequence[Request],
                       prompts: Dict[int, np.ndarray],
                       max_wall_s: float = 300.0) -> Dict:
    """Open-loop replay against a **network** front door: each request is
    POSTed to ``/v1/generate`` at its wall-clock arrival offset and its SSE
    stream is consumed on a reader thread (the blocking client needs one
    reader per in-flight stream; the server itself stays single-threaded).

    The HTTP counterpart of :func:`run_open_loop` — the engine runs in the
    server process, so this driver only paces arrivals and collects tokens.
    ``client`` is a ``repro.frontend.client.EngineHttpClient``. Returns
    ``{"handles", "finished", "unfinished", "wall"}`` keyed by *workload*
    rid (the server assigns its own rids; ``handle.rid`` has the remote
    one)."""
    import threading

    order = sorted(requests, key=lambda r: r.arrival)
    t0 = time.perf_counter()
    t_end = t0 + max_wall_s
    handles: Dict[int, object] = {}
    readers: List[threading.Thread] = []
    for r in order:
        wait = r.arrival - (time.perf_counter() - t0)
        if wait > 0:
            time.sleep(min(wait, max(t_end - time.perf_counter(), 0.0)))
        if time.perf_counter() >= t_end:
            break
        h = client.generate(prompts[r.rid].tolist(),
                            slo_class=r.slo_class, max_output=r.max_output,
                            eos_id=r.eos_id, stop_ids=r.stop_ids)
        handles[r.rid] = h
        th = threading.Thread(target=h.result, daemon=True)
        th.start()
        readers.append(th)
    for th in readers:
        th.join(timeout=max(t_end - time.perf_counter(), 0.0))
    finished = [h for h in handles.values()
                if h.finished and not h.aborted]
    return {
        "handles": handles,
        "finished": finished,
        "unfinished": [h for h in handles.values() if not h.finished],
        "wall": time.perf_counter() - t0,
    }
