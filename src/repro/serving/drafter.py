"""Model-free draft proposers for speculative decoding.

The engine asks a drafter for up to ``k`` candidate continuation tokens per
decode-eligible request per round; candidates execute as one multi-token
verify row through the fused paged-prefill path and are accepted/rejected on
device (see ``models.model.paged_spec_step``). The interface is deliberately
minimal so a real draft model (a small on-device LM sharing the readback, or
a tree/medusa-style proposer) can slot in later: anything with
``propose(context, k) -> Optional[np.ndarray]`` works.

The first cut is **prompt lookup** (n-gram) drafting: find the most recent
earlier occurrence of the transcript's trailing n-gram and propose the
tokens that followed it. Free (no model call, pure host numpy on arrays the
engine already holds), and effective exactly where speculation pays —
repetitive or reference-heavy continuations (code, extraction, multi-turn
chat re-quoting its own context).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np


class DrafterBase:
    """Draft-proposal interface. ``context`` is the request's full visible
    transcript (prompt + emitted tokens, int32) and ``k`` the maximum drafts
    wanted; return up to ``k`` proposed next tokens, or ``None``/empty when
    there is nothing worth proposing (the engine then runs a plain decode
    row — never a degenerate 0-draft verify row)."""

    def propose(self, context: np.ndarray, k: int) -> Optional[np.ndarray]:
        raise NotImplementedError


class NGramDrafter(DrafterBase):
    """Prompt-lookup drafting: match the transcript's trailing ``n``-gram
    (longest first, ``max_ngram`` down to ``min_ngram``) against the rest of
    the transcript and propose the continuation of the most recent prior
    occurrence."""

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        assert 1 <= min_ngram <= max_ngram
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, context: Sequence[int], k: int) -> Optional[np.ndarray]:
        ctx = np.asarray(context, np.int32)
        L = len(ctx)
        if k <= 0 or L < self.min_ngram + 1:
            return None
        for n in range(min(self.max_ngram, L - 1), self.min_ngram - 1, -1):
            tgt = ctx[L - n:]
            win = np.lib.stride_tricks.sliding_window_view(ctx, n)
            hits = np.flatnonzero((win == tgt).all(axis=1))
            # the last window IS the target; earlier hits are real matches.
            # The continuation may overlap the suffix itself — that is the
            # classic repetition case and exactly what we want to propose.
            hits = hits[hits < L - n]
            if len(hits) == 0:
                continue
            i = int(hits[-1])
            cont = ctx[i + n:i + n + k]
            if len(cont):
                return cont.astype(np.int32)
        return None
