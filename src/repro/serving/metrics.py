"""SLO metrics: violation rates, latency percentiles, goodput (paper §5).

Goodput follows the paper's definition: requests served per second while
meeting latency targets, allowing at most ``violation_cap`` (1%) of requests
to violate their SLO; the *maximum* goodput is found by searching QPS.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import Request


def _pct(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else math.nan


def summarize(requests: Sequence[Request], duration: float) -> Dict:
    done = [r for r in requests if r.first_token_time is not None]
    viol = [r.violations() for r in requests]
    ttft = [r.first_token_time - r.arrival for r in done]
    e2e = [r.finish_time - r.arrival for r in requests if r.finish_time is not None]
    # Dimensionless TTFT slowdown: measured TTFT over the request's
    # exclusive-service prefill time. Only requests whose generator stamped
    # a real baseline participate — ``exclusive_ttft`` defaults to 0.0, and
    # dividing by the old 1e-9 guard inflated the percentile to ~1e9 for
    # every workload that never set it. Exclusive service is a lower bound
    # on TTFT, so the ratio is clamped at 1.0 (timer jitter can measure a
    # hair under it).
    ttft_slowdown = [
        max((r.first_token_time - r.arrival) / r.exclusive_ttft, 1.0)
        for r in done if r.exclusive_ttft > 0.0
    ]
    n = max(len(requests), 1)
    ok = sum(1 - v["violated"] for v in viol)
    finished = [r for r in requests if r.finish_time is not None]
    return {
        "n_requests": len(requests),
        "n_finished": len(finished),
        "violation_rate": sum(v["violated"] for v in viol) / n,
        "ttft_miss_rate": sum(v["ttft_miss"] for v in viol) / n,
        "tbt_miss_tokens": sum(v["tbt_misses"] for v in viol),
        "goodput_rps": ok / max(duration, 1e-9),
        "throughput_rps": len(finished) / max(duration, 1e-9),
        "ttft_p50": _pct(ttft, 50), "ttft_p95": _pct(ttft, 95), "ttft_p99": _pct(ttft, 99),
        "e2e_p50": _pct(e2e, 50), "e2e_p95": _pct(e2e, 95), "e2e_p99": _pct(e2e, 99),
        "ttft_slowdown_p50": _pct(ttft_slowdown, 50),
        "ttft_slowdown_p99": _pct(ttft_slowdown, 99),
        "duration": duration,
    }


def summarize_by_class(requests: Sequence[Request], duration: float) -> Dict:
    """Per-SLO-class violation / goodput breakdown: :func:`summarize` on each
    named class's subset. The aggregate number hides *which tenant class*
    pays the violations — with class-weighted admission/eviction in one
    engine, the per-class split is the signal (``interactive`` should hold
    its SLO while ``batch`` absorbs the pressure)."""
    return {
        cls: summarize([r for r in requests if r.slo_class == cls], duration)
        for cls in sorted({r.slo_class for r in requests})
    }


def cumulative_violations(requests: Sequence[Request], horizon: float,
                          step: float = 10.0) -> List:
    """Violation count over time (paper Fig. 6): a request counts at the
    moment its first deadline is irrecoverably missed."""
    times = []
    for r in requests:
        v = r.violations()
        if v["ttft_miss"]:
            times.append(r.first_token_time if r.first_token_time is not None
                         else r.ttft_deadline())
        elif v["tbt_misses"]:
            for k, tt in enumerate(r.token_times[1:], start=2):
                if tt > r.token_deadline(k) + 1e-9:
                    times.append(tt)
                    break
    times.sort()
    grid = np.arange(0.0, horizon + step, step)
    counts = np.searchsorted(times, grid)
    return list(zip(grid.tolist(), counts.tolist()))


def max_goodput(run_at_qps: Callable[[float], Dict], lo: float, hi: float,
                violation_cap: float = 0.01, iters: int = 7) -> Dict:
    """Binary-search the highest QPS whose violation rate stays under cap.

    ``run_at_qps(qps) -> summarize(...) dict``. Returns the frontier point.
    """
    best = None
    res_lo = run_at_qps(lo)
    if res_lo["violation_rate"] > violation_cap:
        return {"qps": 0.0, "summary": res_lo}
    best = (lo, res_lo)
    res_hi = run_at_qps(hi)
    if res_hi["violation_rate"] <= violation_cap:
        return {"qps": hi, "summary": res_hi}
    for _ in range(iters):
        mid = (lo + hi) / 2
        res = run_at_qps(mid)
        if res["violation_rate"] <= violation_cap:
            lo, best = mid, (mid, res)
        else:
            hi = mid
    return {"qps": best[0], "summary": best[1]}
