"""Analytic iteration-latency model (the simulator's ground truth).

The paper measures real GPU batch latencies on RTX3090/A6000/A100; this repo
targets TPU v5e, where we cannot measure wall-clock in this container. The
simulator therefore executes batches against a *roofline-derived* latency
model: per-iteration time is ``overhead + max(T_compute, T_memory)`` with

    T_compute = FLOPs(batch)   / (chips * peak_flops * eff)
    T_memory  = bytes(batch)   / (chips * hbm_bw * eff)

FLOPs/bytes are computed from the (c_i, u_i) batch composition exactly as the
paper's feature table decomposes them (linear-proj term ~ S, prefill attention
~ sum c_i (u_i + c_i), KV reads ~ sum u_i, weight reads once per batch). The
model is intentionally *nonlinear* in the scheduler's features (the max() and
the per-scene regimes) — the per-scene linear predictor has to learn it from
observed samples, which is precisely the paper's setting.

Multiplicative lognormal noise models runtime jitter.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ATTN, LOCAL_ATTN, MAMBA, MLA, MLSTM, MOE, SLSTM, ModelConfig


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link
    hbm_bytes: float = 16e9
    chips: int = 1                      # model-parallel group size
    eff_compute: float = 0.6            # achievable fraction of peak
    eff_mem: float = 0.75
    iter_overhead: float = 3e-4         # dispatch/sync per iteration (s)


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Scalar coefficients for batch cost, derived from a ModelConfig."""

    name: str
    param_bytes: float            # weight bytes read per iteration (active set)
    flops_per_token: float        # 2 * N_active (linear/proj work per token)
    attn_flops_coef: float        # FLOPs per c*(u+c) unit (QK^T + PV, all layers)
    kv_bytes_per_token: float     # KV-cache bytes per cached token (all layers)
    state_bytes_per_req: float    # fixed recurrent state bytes (mamba/xlstm)
    window: int = 0               # sliding-window cap on attention context

    @staticmethod
    def from_config(cfg: ModelConfig, bytes_per_param: float = 2.0) -> "ModelProfile":
        Dh = cfg.resolved_head_dim
        n_active = cfg.param_count(active_only=True)
        kinds = [(ATTN, "dense")] * cfg.first_k_dense + cfg.layer_kinds()
        attn_coef = 0.0
        kv_bytes = 0.0
        state_bytes = 0.0
        window = 0
        for mixer, _ in kinds:
            if mixer in (ATTN, LOCAL_ATTN):
                attn_coef += 2 * 2 * cfg.num_heads * Dh
                kv_bytes += 2 * cfg.num_kv_heads * Dh * bytes_per_param
                if mixer == LOCAL_ATTN:
                    window = cfg.sliding_window
            elif mixer == MLA:
                attn_coef += 2 * 2 * cfg.num_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
                kv_bytes += (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * bytes_per_param
            elif mixer == MAMBA:
                di = cfg.mamba_expand * cfg.d_model
                state_bytes += di * (cfg.mamba_d_state * 4 + cfg.mamba_d_conv * 2)
            elif mixer in (MLSTM, SLSTM):
                di = 2 * cfg.d_model
                state_bytes += (di // cfg.num_heads) * di * 4 if mixer == MLSTM else di * 16
        return ModelProfile(
            name=cfg.name,
            param_bytes=n_active * bytes_per_param,
            flops_per_token=2.0 * n_active,
            attn_flops_coef=float(attn_coef),
            kv_bytes_per_token=float(kv_bytes),
            state_bytes_per_req=float(state_bytes),
            window=window,
        )


class CostModel:
    """Ground-truth batch latency. Batch = [(c_i, u_i)] per paper §3.2."""

    def __init__(self, profile: ModelProfile, hw: HardwareSpec,
                 noise_sigma: float = 0.03, seed: int = 0):
        self.profile = profile
        self.hw = hw
        self.noise_sigma = noise_sigma
        self._rng = np.random.default_rng(seed)

    # ---- deterministic terms -------------------------------------------------
    def flops(self, batch: Sequence[Tuple[int, int]]) -> float:
        p = self.profile
        total = 0.0
        for c, u in batch:
            ctx = u + c
            if p.window:
                ctx = min(ctx, p.window)  # banded layers cap context (approx.)
            total += c * p.flops_per_token + p.attn_flops_coef * c * ctx
        return total

    def bytes_moved(self, batch: Sequence[Tuple[int, int]]) -> float:
        p = self.profile
        total = p.param_bytes  # weights stream once per iteration
        for c, u in batch:
            total += p.kv_bytes_per_token * (u + c)     # KV read + write
            total += p.state_bytes_per_req               # recurrent state r/w
            total += c * 2 * 4096.0                      # activations (approx)
        return total

    def latency(self, batch: Sequence[Tuple[int, int]], noisy: bool = True) -> float:
        if not batch:
            return 0.0
        hw = self.hw
        t_comp = self.flops(batch) / (hw.chips * hw.peak_flops * hw.eff_compute)
        t_mem = self.bytes_moved(batch) / (hw.chips * hw.hbm_bw * hw.eff_mem)
        t = hw.iter_overhead + max(t_comp, t_mem)
        if noisy and self.noise_sigma > 0:
            t *= float(self._rng.lognormal(0.0, self.noise_sigma))
        return t

    def exclusive_prefill_time(self, prompt_len: int) -> float:
        """Latency of prefilling the whole prompt alone (TTFT slowdown base)."""
        return self.latency([(prompt_len, 0)], noisy=False)

    def decode_token_time(self, context: int) -> float:
        return self.latency([(1, context)], noisy=False)
