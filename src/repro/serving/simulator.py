"""Event-driven serving simulator (the paper's evaluation harness).

Executes a scheduler against the analytic ground-truth cost model: each round
the scheduler emits a request-level token allocation; the simulator charges
the batch's (noisy) latency, advances request state — chunked prefill
progress, first-token emission when prefill completes, one token per decode
request (or ``1 + accepted`` with speculative decoding on: verify rows are
priced at ``1 + spec_k`` tokens and serve a sampled accepted chain) —
enforces paged-KV admission/preemption, and feeds the observed latency back
to the scheduler's online predictor. Wall-clock in the simulated
timeline is exact; the Python loop itself is cheap.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler import Decision, KVPressure, SchedulerBase
from repro.serving.block_allocator import BlockAllocator
from repro.serving.costmodel import CostModel
from repro.serving.request import ReqState, Request


@dataclasses.dataclass
class SimResult:
    requests: List[Request]
    duration: float
    iterations: int
    route_counts: Dict[str, int]
    trace: List[Tuple[float, float, int]]  # (t, latency, scheduled_tokens)


class ServingSimulator:
    def __init__(self, scheduler: SchedulerBase, cost_model: CostModel,
                 workload: Sequence[Request], *,
                 kv_capacity_tokens: int = 512 * 1024,
                 block_size: int = 16,
                 decode_reserve_tokens: int = 64,
                 max_sim_time: float = 1e9,
                 warmup_predictor: bool = True,
                 collect_trace: bool = False,
                 spec_k: int = 0,
                 spec_acceptance: float = 0.0,
                 spec_seed: int = 0):
        self.sched = scheduler
        self.cost = cost_model
        self.workload = sorted(workload, key=lambda r: r.arrival)
        self.alloc = BlockAllocator(kv_capacity_tokens, block_size)
        self.decode_reserve = decode_reserve_tokens
        self.max_sim_time = max_sim_time
        self.collect_trace = collect_trace
        self._last_round_evictions = 0
        # speculative decoding: each decode row is priced as a (1 + spec_k)-
        # token verify row (the drafted tokens ride the dispatch whether or
        # not they are accepted) and serves 1 + a tokens, a drawn as a chain
        # of per-draft accepts at ``spec_acceptance`` — the engine-measured
        # rate (see bench_goodput --spec-k, which feeds it in)
        self.spec_k = int(spec_k)
        self.spec_acceptance = float(spec_acceptance)
        self.spec_rows = 0
        self.spec_emitted = 0
        if self.spec_k:
            import numpy as np
            self._spec_rng = np.random.default_rng(spec_seed)
        if warmup_predictor:
            self._offline_calibration()

    # ---- offline predictor init (paper §3.2 "offline initialization") ---------
    def _offline_calibration(self, n: int = 600, seed: int = 1234):
        import numpy as np
        rng = np.random.default_rng(seed)
        samples = []
        for _ in range(n):
            nd = int(rng.integers(0, 48))
            np_ = int(rng.integers(0, 5))
            batch = [(1, int(rng.integers(16, 8192))) for _ in range(nd)]
            batch += [(int(rng.integers(2, 2048)), int(rng.integers(0, 8192)))
                      for _ in range(np_)]
            if not batch:
                continue
            samples.append((batch, self.cost.latency(batch, noisy=True)))
        self.sched.predictor.fit_offline(samples)

    # ---- main loop --------------------------------------------------------------
    def run(self) -> SimResult:
        t = 0.0
        pending = list(self.workload)   # not yet arrived
        waiting: List[Request] = []
        active: List[Request] = []      # prefilling or decoding, KV-resident
        iterations = 0
        route_counts: Dict[str, int] = {}
        trace: List[Tuple[float, float, int]] = []

        def admit_arrivals(now: float):
            while pending and pending[0].arrival <= now:
                waiting.append(pending.pop(0))

        while (pending or waiting or active) and t < self.max_sim_time:
            admit_arrivals(t)

            # KV admission: move waiting -> active when the prompt + reserve
            # fits; the blocks are *reserved* at admit time so concurrent
            # admits are gated by the same free pool.
            still_waiting: List[Request] = []
            for r in waiting:
                if self.alloc.admit(r.rid,
                                    r.remaining_prefill() + self.decode_reserve):
                    active.append(r)
                else:
                    still_waiting.append(r)
            waiting = still_waiting

            # admitted-but-unstarted requests are offered as ``waiting`` so
            # MLPS ordering applies to them; KV pressure lets the scheduler
            # cap chunk budgets before growth failures force evictions.
            wait_adm = [r for r in active if r.state == ReqState.WAITING]
            prefilling = [r for r in active if r.state == ReqState.PREFILLING]
            decoding = [r for r in active if r.state == ReqState.DECODING]
            # pressure tracks tokens actually computed, not reservations —
            # reserved prompt space is what scheduled prefill consumes
            capacity = self.alloc.num_blocks * self.alloc.block_size
            computed = sum(r.context_len() for r in active)
            kv = KVPressure(utilization=computed / capacity,
                            free_tokens=capacity - computed,
                            evictions=self._last_round_evictions)

            decision = self.sched.schedule(t, wait_adm, prefilling, decoding,
                                           kv=kv)
            if decision is None or not decision.alloc:
                if pending:
                    t = max(t, pending[0].arrival)
                    continue
                break

            batch = decision.batch()
            if self.spec_k:
                batch = [(n + (self.spec_k if r.state == ReqState.DECODING
                               else 0), r.context_len())
                         for r, n in decision.alloc]
            latency = self.cost.latency(batch, noisy=True)
            t += latency
            iterations += 1
            route_counts[decision.route] = route_counts.get(decision.route, 0) + 1
            if self.collect_trace:
                trace.append((t, latency, sum(c for c, _ in batch)))

            finished: List[Request] = []
            ev0 = self.alloc.evictions
            for req, n in decision.alloc:
                if req.rid not in self.alloc.owners:
                    continue   # evicted by an earlier entry's growth this round
                if req.state == ReqState.DECODING:
                    serve = 1
                    if self.spec_k:
                        while (serve <= self.spec_k and self._spec_rng.random()
                               < self.spec_acceptance):
                            serve += 1
                        self.spec_rows += 1
                        self.spec_emitted += serve
                    for _ in range(serve):
                        if req.state != ReqState.DECODING:
                            break   # accepted tail past max_output: dropped
                        if not self.alloc.grow(req.rid, req.context_len() + 1):
                            self._evict_for(req, active, waiting)
                            if not self.alloc.grow(req.rid,
                                                   req.context_len() + 1):
                                break   # capacity exhausted: token not served
                        req.emit_token(t)
                else:
                    self.alloc.grow(req.rid, req.prefilled + n)
                    req.advance_prefill(n)
                    if req.remaining_prefill() == 0:
                        req.emit_token(t)  # prefill completion emits token 1
                if req.state == ReqState.FINISHED:
                    finished.append(req)
            for req in finished:
                self.alloc.free(req.rid)
                active.remove(req)

            self._last_round_evictions = self.alloc.evictions - ev0
            computed = sum(r.context_len() for r in active)
            self.sched.observe(batch, latency,
                               kv=KVPressure(computed / capacity,
                                             capacity - computed,
                                             self._last_round_evictions))
            self.alloc.check_invariants()

        return SimResult(requests=list(self.workload), duration=t,
                         iterations=iterations, route_counts=route_counts,
                         trace=trace)

    # ---- preemption ---------------------------------------------------------------
    def _evict_for(self, needy: Request, active: List[Request],
                   waiting: List[Request]) -> None:
        """Free blocks by relegating the lowest-priority non-needy owner
        (allocator ``pick_victim``: newest arrival first — the shared
        vLLM-style recompute policy): its cache is dropped, prefill restarts."""
        by_rid = {r.rid: r for r in active}
        # always free at least one block (the caller's grow just failed);
        # decode_reserve may be 0 or below the block size
        target = max(self.decode_reserve, 1)
        while self.alloc.free_blocks * self.alloc.block_size < target:
            vid = self.alloc.pick_victim(
                needy.rid, priority=lambda rid: by_rid[rid].arrival
                if rid in by_rid else -1.0)
            if vid is None or vid not in by_rid:
                return
            v = by_rid.pop(vid)
            self.alloc.evict(v.rid)
            active.remove(v)
            v.state = ReqState.WAITING
            v.prefilled = 0
            waiting.append(v)
