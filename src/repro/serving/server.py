"""Streaming inference frontend over the step-based :class:`EngineCore`.

``InferenceServer`` is the online entry point the paper's setting actually
needs: requests **arrive continuously** (``submit`` at any time, no upfront
request list), tokens **stream incrementally** to each caller
(``handle.tokens()`` yields ids as the engine's per-round readbacks surface
them), and requests **leave early** (``handle.cancel()`` frees KV pages /
slots mid-prefill or mid-decode). Tenants are mixed in one engine through
named **SLO classes** — ``interactive`` / ``standard`` / ``batch`` — each a
(ttft, tbt) deadline pair the scheduler's MLPS sorter and violation checker
consume, so one paged KV pool serves chatbots next to offline summarizers.

The server is cooperative and single-threaded, like the engine itself: every
``step()``/``run()``/``tokens()`` call pumps ``EngineCore.step()`` and routes
the returned :class:`EngineEvent` stream into per-request handles. Nothing
here syncs with the device beyond the engine's one deferred readback per
round — streaming keeps the zero-sync hot path intact (token events simply
surface one round after dispatch).

    server = InferenceServer.build(cfg, cache_mode="paged")
    h = server.submit(prompt_ids, slo_class="interactive", max_output=32)
    for tok in h.tokens():      # pumps the engine; yields ids incrementally
        ...
    h2.cancel()                 # aborts; pages return to the BlockAllocator
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import SlidingServeScheduler
from repro.serving.engine import EngineCore, EngineEvent, EventKind
from repro.serving.request import ReqState, Request


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A named tenant class: deadlines every request of the class inherits.

    ``ttft_slo`` seconds from arrival to the first token, ``tbt_slo`` seconds
    between subsequent tokens (paper Eq. 1 per-token deadlines)."""

    name: str
    ttft_slo: float
    tbt_slo: float


# Default tenant classes. The paper's Table-3 workload SLOs (``dialogue``,
# ``summarization``) are *dataset*-derived; these are the serving-facing
# knobs an operator names at submit time.
SLO_CLASSES: Dict[str, SLOClass] = {
    "interactive": SLOClass("interactive", ttft_slo=1.0, tbt_slo=0.05),
    "standard": SLOClass("standard", ttft_slo=5.0, tbt_slo=0.25),
    "batch": SLOClass("batch", ttft_slo=60.0, tbt_slo=2.0),
}


class StreamHandle:
    """One submitted request's streaming view.

    ``tokens()`` is an incremental iterator fed by the engine's TOKEN events:
    it yields ids already buffered, and when the buffer runs dry it pumps the
    server until more arrive or the request finishes. ``cancel()`` aborts the
    request (idempotent; buffered tokens remain readable)."""

    def __init__(self, server: "InferenceServer", request: Request):
        self._server = server
        self.request = request
        self.rid = request.rid
        self.collected: List[int] = []     # every token id received so far
        self._buf: collections.deque = collections.deque()
        self.finished = False
        self.finish_reason = ""            # "length" | "stop" | "aborted"
        self.first_token_t: Optional[float] = None

    # ---- event sink (called by the server's router) -------------------------
    def _on_event(self, ev: EngineEvent) -> None:
        if ev.kind in (EventKind.FIRST_TOKEN, EventKind.TOKEN):
            if ev.kind is EventKind.FIRST_TOKEN:
                self.first_token_t = ev.t
            self.collected.append(ev.token)
            self._buf.append(ev.token)
        elif ev.kind is EventKind.FINISHED:
            self.finished = True
            self.finish_reason = ev.reason or "length"
        elif ev.kind is EventKind.ABORTED:
            self.finished = True
            self.finish_reason = "aborted"

    # ---- client surface ------------------------------------------------------
    @property
    def aborted(self) -> bool:
        return self.finish_reason == "aborted"

    def cancel(self) -> None:
        self._server.cancel(self.rid)

    def poll(self) -> List[int]:
        """Non-blocking drain of tokens already routed to this handle — no
        engine pumping, no waiting (the HTTP transport and the router pump
        the engine from one place and poll handles from another)."""
        out: List[int] = []
        while self._buf:
            out.append(self._buf.popleft())
        return out

    def tokens(self, max_wall_s: float = 600.0) -> Iterator[int]:
        """Yield output token ids as they stream in, pumping the engine while
        waiting. Returns when the request finishes (length / stop / cancel);
        raises TimeoutError if the engine cannot produce progress in time and
        RuntimeError if the request can never be admitted (wedged queue)."""
        deadline = time.perf_counter() + max_wall_s
        stall = 0
        while True:
            while self._buf:
                yield self._buf.popleft()
            if self.finished:
                return
            if time.perf_counter() > deadline:
                raise TimeoutError(f"rid {self.rid}: no progress")
            core = self._server.core
            self._server.step()
            if core.stalled():
                # nothing can progress (queue won't fit / request outgrew
                # capacity): fail fast instead of busy-polling the budget
                stall += 1
                if stall >= 8:
                    raise RuntimeError(
                        f"rid {self.rid}: engine wedged (work cannot be "
                        f"admitted or fit — prompt larger than the KV pool?)")
            else:
                stall = 0
            if not self._buf and not self.finished:
                self._server._idle_wait()

    def result(self, max_wall_s: float = 600.0) -> List[int]:
        """Block until finished; returns the complete output id list."""
        for _ in self.tokens(max_wall_s):
            pass
        return list(self.collected)


class InferenceServer:
    """Submit/cancel frontend driving ``EngineCore.step()``.

    One server wraps one engine. ``submit`` assigns rids, stamps arrivals on
    the engine clock, and maps an :data:`SLO_CLASSES` name onto the request's
    (ttft, tbt) deadlines; ``step``/``run`` pump the engine and fan events
    out to handles.

    Lifetime note: finished handles (with their token lists) and the
    ``events`` log are retained for inspection — per-run drivers and
    benchmarks read them after the fact. A service wrapper holding one
    server for days should ``release(rid)`` handles it has consumed and
    truncate ``events`` periodically; the engine frees the expensive state
    (KV pages, prompt arrays) at retirement on its own."""

    def __init__(self, core: EngineCore,
                 slo_classes: Optional[Dict[str, SLOClass]] = None):
        self.core = core
        self.slo_classes = dict(slo_classes or SLO_CLASSES)
        self.handles: Dict[int, StreamHandle] = {}
        self.events: List[EngineEvent] = []    # full event log (diagnostics)
        self._next_rid = 0
        self._subscribers: List = []           # event taps (HTTP transport)
        self._draining = False                 # close() in progress/complete
        self._close_report: Optional[Dict] = None

    def subscribe(self, fn) -> None:
        """Register an event tap: ``fn(event)`` is called for every routed
        :class:`EngineEvent`, in order, from whichever thread pumps the
        server. The HTTP transport uses this to feed per-request SSE queues
        without polling handles."""
        self._subscribers.append(fn)

    def has_work(self) -> bool:
        return self.core.has_work()

    @classmethod
    def build(cls, cfg, scheduler=None, slo_classes=None, **engine_kw
              ) -> "InferenceServer":
        """Convenience constructor: engine + default SlidingServe scheduler."""
        sched = scheduler or SlidingServeScheduler(max_budget=512,
                                                   max_iter_time=2.0)
        return cls(EngineCore(cfg, sched, **engine_kw),
                   slo_classes=slo_classes)

    # ---- submission ----------------------------------------------------------
    def submit(self, prompt: Sequence[int], slo_class: str = "standard",
               max_output: int = 64, eos_id: Optional[int] = None,
               stop_ids: Tuple[int, ...] = (),
               rid: Optional[int] = None) -> StreamHandle:
        """Submit a prompt under a named SLO class; returns its stream handle.
        The request arrives *now* on the engine clock — deadlines run from
        this call. ``rid`` pins an externally assigned request id (the
        multi-replica router owns the global id space); default is the
        server's own counter."""
        cls = self.slo_classes[slo_class]
        prompt = np.asarray(prompt, np.int32)
        req = Request(rid=self._alloc_rid() if rid is None else rid,
                      arrival=self.core.now(),
                      prompt_len=len(prompt), max_output=max_output,
                      ttft_slo=cls.ttft_slo, tbt_slo=cls.tbt_slo,
                      slo_class=cls.name, eos_id=eos_id,
                      stop_ids=tuple(stop_ids))
        return self.submit_request(req, prompt)

    def submit_request(self, req: Request, prompt: Sequence[int]
                       ) -> StreamHandle:
        """Submit a pre-built :class:`Request` (workload replay: the request
        carries its own SLOs and an engine-clock ``arrival``). A *past*
        arrival is kept — SLO clocks then run from the scheduled arrival, so
        submission delay counts as queueing time exactly as ``serve()``
        measures it; a future arrival is clamped to now (the streaming API
        has no scheduled future — submit when the request exists)."""
        if self._draining:
            raise RuntimeError("InferenceServer is draining/closed: "
                               "no new admissions")
        req.arrival = min(req.arrival, self.core.now())
        self._next_rid = max(self._next_rid, req.rid + 1)
        handle = StreamHandle(self, req)
        self.handles[req.rid] = handle
        self.core.add_request(req, prompt)
        return handle

    def _alloc_rid(self) -> int:
        rid = self._next_rid
        self._next_rid += 1
        return rid

    def release(self, rid: int) -> None:
        """Forget a finished/aborted handle (long-running servers call this
        after consuming a stream so handle memory doesn't accumulate)."""
        h = self.handles.get(rid)
        if h is not None and h.finished:
            del self.handles[rid]

    # ---- engine pumping ------------------------------------------------------
    def cancel(self, rid: int) -> bool:
        """Abort ``rid`` (frees its KV pages / slot). True if it was live."""
        h = self.handles.get(rid)
        was_live = h is not None and not h.finished
        self._route(self.core.abort(rid))
        return was_live and h.finished

    def step(self) -> List[EngineEvent]:
        """One engine round; routes and returns its events."""
        evts = self.core.step()
        self._route(evts)
        return evts

    def _route(self, evts: List[EngineEvent]) -> None:
        self.events.extend(evts)
        for ev in evts:
            h = self.handles.get(ev.rid)
            if h is not None:
                h._on_event(ev)
            for fn in self._subscribers:
                fn(ev)

    def _idle_wait(self) -> None:
        """Pacing between unproductive rounds, mirroring serve(): wait for
        the next scheduled arrival when idle, yield briefly otherwise."""
        p = self.core.progress
        if p == "executed":
            return
        nxt = self.core.next_arrival()
        if p == "idle" and nxt is not None:
            time.sleep(max(nxt - self.core.now(), 0.0) + 1e-4)
        else:
            time.sleep(1e-3)

    def run(self, max_wall_s: float = 600.0) -> List[EngineEvent]:
        """Drive the engine until it drains (or the wall budget expires);
        returns the events of this run segment."""
        n0 = len(self.events)
        t_end = time.perf_counter() + max_wall_s
        stall = 0
        while self.core.has_work() and time.perf_counter() < t_end:
            self.step()
            if self.core.progress == "executed":
                stall = 0
                continue
            # wedge guard (the engine's shared predicate, as serve() uses):
            # unprogressable work must not spin to the wall clock.
            stall = stall + 1 if self.core.stalled() else 0
            if stall >= 8:
                break
            self._idle_wait()
        # abnormal exits (wall budget, wedge) can leave the last dispatched
        # round unread; settle it so its tokens reach the handles.
        self._route(self.core.flush())
        return self.events[n0:]

    # ---- graceful shutdown ---------------------------------------------------
    @property
    def draining(self) -> bool:
        return self._draining

    def close(self, drain_s: float = 30.0) -> Dict:
        """Graceful shutdown: stop admitting, drain in-flight requests to
        completion (or the ``drain_s`` deadline), then abort stragglers —
        with KV pages / slots verifiably reclaimed either way. Idempotent;
        returns ``{"drained", "finished", "aborted"}``. After close, every
        handle is settled (finished or aborted) and ``submit`` raises."""
        if self._close_report is not None:
            return self._close_report
        self._draining = True
        t_end = time.perf_counter() + max(drain_s, 0.0)
        stall = 0
        while self.core.has_work() and time.perf_counter() < t_end:
            self.step()
            if self.core.progress == "executed":
                stall = 0
                continue
            stall = stall + 1 if self.core.stalled() else 0
            if stall >= 8:
                break               # wedged: fall through to the abort sweep
            self._idle_wait()
        self._route(self.core.flush())
        stragglers = [rid for rid, h in self.handles.items()
                      if not h.finished]
        for rid in stragglers:
            self.cancel(rid)
        # every page/slot must be back in the pool — a leak here would stay
        # invisible until the *next* deployment's admissions start failing.
        core = self.core
        if core.cache_mode == "paged":
            assert core.alloc.free_blocks == core.alloc.num_blocks, \
                "close(): KV pages leaked past drain+abort"
            core.alloc.check_invariants()
        else:
            assert len(core.free_slots) == core.max_slots, \
                "close(): slots leaked past drain+abort"
        self._close_report = {
            "drained": not stragglers,
            "finished": sum(1 for h in self.handles.values()
                            if h.finished and not h.aborted),
            "aborted": len(stragglers),
        }
        return self._close_report

    # ---- reporting -----------------------------------------------------------
    def summary(self) -> Dict:
        from repro.serving.metrics import summarize_by_class
        reqs = [h.request for h in self.handles.values()]
        fin = [r for r in reqs if r.state == ReqState.FINISHED]
        return {
            "submitted": len(reqs),
            "finished": len(fin),
            "aborted": sum(1 for r in reqs if r.state == ReqState.ABORTED),
            "violations": sum(r.violations()["violated"] for r in fin),
            "per_class": summarize_by_class(reqs, max(self.core.now(), 1e-9)),
            "stats": self.core.stats,
        }

    def stats_snapshot(self) -> Dict:
        """JSON-able operational snapshot (the HTTP ``GET /v1/stats`` body):
        EngineStats counters, prefix-cache accounting, per-class metrics and
        live queue/outstanding-work gauges."""
        core = self.core
        summ = self.summary()
        return {
            "engine": dataclasses.asdict(summ.pop("stats")),
            "cache_info": core.cache_info(),
            "sharding": core.shard_info(),
            "queue_depth": core.queue_depth,
            "outstanding_tokens": core.outstanding_tokens(),
            "draining": self._draining,
            **summ,
        }
