"""Unified request model with SLO constraints (paper §2.1).

TTFT/TBT are modeled exactly as the paper does: TBT is a set of *per-token
deadlines* (Eq. 1): the (k+1)-th output token of request i is due at

    d_{i,k+1} = a_i + L_ttft + k * L_tbt.

TTFT SLOs in the evaluation are specified as *max TTFT slowdown* relative to
exclusive service (paper Table 3), so ``ttft_slo`` is materialized per request
as ``slowdown * exclusive_prefill_time`` by the workload generator.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Tuple


class ReqState(enum.Enum):
    WAITING = "waiting"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"
    ABORTED = "aborted"          # cancelled by the client (EngineCore.abort)


# Admission/eviction rank of the named SLO classes (lower = more
# latency-critical). The engine admits lower ranks first (FIFO preserved
# within a class) and never evicts a lower-rank owner to grow a higher-rank
# request — concretely: never evict ``interactive`` to grow ``batch``.
# Unknown/legacy class names rank with ``standard`` so single-class
# workloads behave exactly as before.
SLO_CLASS_RANK = {
    "interactive": 0,
    "dialogue": 1,        # the paper's dataset-derived classes
    "standard": 1,
    "summarization": 2,
    "batch": 2,
}
DEFAULT_CLASS_RANK = 1


def class_rank(name: str) -> int:
    return SLO_CLASS_RANK.get(name, DEFAULT_CLASS_RANK)


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    max_output: int
    ttft_slo: float                  # seconds from arrival to first token
    tbt_slo: float                   # seconds between subsequent tokens
    guard: bool = False              # safeguard flag g_i (paper §3.3)
    slo_class: str = "dialogue"
    # stop-token termination: generation ends early when the sampled token is
    # ``eos_id`` or any member of ``stop_ids`` (the stop token itself is the
    # final emitted token). ``max_output`` stays the hard length cap. The
    # engine checks these against the token ids of its one deferred readback
    # per round, so stop termination adds no device→host sync.
    eos_id: Optional[int] = None
    stop_ids: Tuple[int, ...] = ()

    # --- runtime state -------------------------------------------------------
    state: ReqState = ReqState.WAITING
    prefilled: int = 0               # c_i(t): prompt tokens already computed
    cached_prefix: int = 0           # prompt tokens served by the prefix cache
                                     # at admission (counted inside prefilled)
    generated: int = 0               # output tokens emitted
    recomputed: int = 0              # emitted tokens folded into the prompt by
                                     # evict-and-recompute (still in generated)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)
    exclusive_ttft: float = 0.0      # prefill time under exclusive service

    # ---- paper quantities ---------------------------------------------------
    def remaining_prefill(self) -> int:
        """r_i(t) = p_i - c_i(t)  (Eq. 7)."""
        return self.prompt_len - self.prefilled

    def ttft_deadline(self) -> float:
        return self.arrival + self.ttft_slo

    def ttft_slack(self, t: float) -> float:
        """s_i(t) = a_i + L_ttft - t  (Eq. 8)."""
        return self.ttft_deadline() - t

    def token_deadline(self, k: int) -> float:
        """Deadline of the k-th output token, k >= 1  (Eq. 1)."""
        return self.arrival + self.ttft_slo + (k - 1) * self.tbt_slo

    def next_token_deadline(self) -> float:
        return self.token_deadline(self.generated + 1)

    def decode_slack(self, t: float) -> float:
        return self.next_token_deadline() - t

    def sched_decode_slack(self, t: float) -> float:
        """Slack used for *scheduling* (not metrics): once a request has
        fallen behind its absolute Eq.-1 schedule, the recoverable target is
        one TBT after its last emitted token — otherwise a single late token
        would pin the whole system's iteration window at ~0 forever."""
        d = self.next_token_deadline()
        if self.token_times:
            d = max(d, self.token_times[-1] + self.tbt_slo)
        return d - t

    # ---- lifecycle ----------------------------------------------------------
    def context_len(self) -> int:
        """u_i: tokens already computed & cached. Tokens an eviction folded
        into the prompt would otherwise be counted by both ``prefilled`` and
        ``generated``."""
        return self.prefilled + self.generated - self.recomputed

    def is_decoding(self) -> bool:
        return self.state == ReqState.DECODING

    def class_rank(self) -> int:
        """Admission/eviction rank of this request's SLO class (lower = more
        latency-critical; see :data:`SLO_CLASS_RANK`)."""
        return class_rank(self.slo_class)

    def hits_stop(self, token: int) -> bool:
        """True when ``token`` terminates generation (EOS / stop set)."""
        return ((self.eos_id is not None and token == self.eos_id)
                or token in self.stop_ids)

    def ttft_violated(self, t: float) -> bool:
        if self.first_token_time is not None:
            return self.first_token_time > self.ttft_deadline()
        return t > self.ttft_deadline()

    def advance_prefill(self, n: int) -> None:
        self.prefilled += n
        assert self.prefilled <= self.prompt_len, (self.rid, self.prefilled, self.prompt_len)
        if self.state == ReqState.WAITING:
            self.state = ReqState.PREFILLING

    def emit_token(self, t: float) -> None:
        self.generated += 1
        self.token_times.append(t)
        if self.first_token_time is None:
            self.first_token_time = t
        self.state = ReqState.DECODING
        if self.generated >= self.max_output:
            self.state = ReqState.FINISHED
            self.finish_time = t

    # ---- SLO accounting (used by metrics) -----------------------------------
    def violations(self) -> dict:
        """Counts of missed deadlines for this (finished or not) request."""
        ttft_miss = (self.first_token_time is None
                     or self.first_token_time > self.ttft_deadline() + 1e-9)
        tbt_misses = sum(
            1 for k, tt in enumerate(self.token_times[1:], start=2)
            if tt > self.token_deadline(k) + 1e-9
        )
        return {
            "ttft_miss": int(ttft_miss),
            "tbt_misses": tbt_misses,
            "violated": int(ttft_miss or tbt_misses > 0),
        }
