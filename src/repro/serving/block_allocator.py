"""Paged KV-cache block allocator with a copy-on-write radix prefix cache.

Token storage is paged into fixed-size blocks; requests own block lists that
grow as prefill/decode advances. The allocator is the *single* admission /
preemption authority shared by the real ``ServingEngine`` and the analytic
``ServingSimulator``: a request is admitted only when its full prompt plus a
decode reserve fits, growth happens per emitted/prefilled token, and decode
growth failures trigger eviction of the lowest-priority owner
(recompute-on-resume policy, ``pick_victim``).

Beyond pure accounting the allocator hands out *physical page ids* from a
free list; the engine turns an owner's ``page_ids`` into the block table rows
the paged attention kernels consume. The analytic simulator ignores the ids
and uses only the counting API — both views are kept consistent by
``check_invariants``. Page ids and token slots (``page*page_size + offset``)
are **layout-independent**: the fused head-interleaved KV pool
(``[Hkv, P, 2, ps, D]``, ``models.model.PAGED_KV_LAYOUT``) changed the
physical bytes behind a page without touching this accounting, the radix
index, or COW semantics — only the engine-side scatter
(``write_pages_fused``) and the kernels interpret the layout.

**Prefix cache (radix/COW layer).** Full pages whose token content is known
can be *committed* into a content index keyed by the chain
``(parent_page_id, page_token_ids)`` — the parent's physical id uniquely
names the whole prefix below it, so lookups are exact (no hash collisions)
and the index is a radix tree over page-granular token runs. Committed pages
carry a **refcount** (how many owners hold them); ``match_prefix`` lets
admission reuse a frozen prefix chain, increfing each matched page instead
of recomputing it. Sharing is copy-on-write in the only form a paged KV
cache needs: shared pages are *never written* (writes land exclusively in
freshly allocated pages at positions past the matched prefix; partial tail
pages are recomputed rather than copied), so no true page copy ever happens.

Page lifecycle is a three-state machine, which is also the eviction tier
order:

    free  <- allocation pops these first
    cached  (refcount 0, still in the index)  <- reclaimed LRU, leaves first,
            invalidating the index entry, *before* any live request is evicted
    live  (refcount > 0)  <- only evict-and-recompute of a whole owner can
            release these, and releasing an owner merely decrefs: a page
            shared with another live owner is never touched

``free_blocks`` reports free + cached (everything obtainable without
relegating a live request), so legacy capacity checks — and the engine's
"pool fully released" leak assertions — keep their meaning with the cache
populated.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

PageKey = Tuple[int, Tuple[int, ...]]   # (parent page id or -1, page tokens)

# Root of every page-chain hash (the hash "above" a prompt's first page).
ROOT_CHAIN = b"\x00" * 8


def page_chain_hash(parent_hash: bytes, chunk: Sequence[int]) -> bytes:
    """Position-independent content name of one page *in its chain*: the
    parent chain hash folded with the page's token ids. Unlike ``PageKey``
    (which names the parent by *physical* page id and is only meaningful
    inside one allocator), chain hashes are stable across engines and
    processes — the cross-engine prefix directory is keyed on them."""
    h = hashlib.blake2b(digest_size=8)
    h.update(parent_hash)
    h.update(" ".join(str(int(t)) for t in chunk).encode())
    return h.digest()


@dataclasses.dataclass
class _Owner:
    rid: int
    blocks: int
    tokens: int
    page_ids: List[int] = dataclasses.field(default_factory=list)
    cached_tokens: int = 0        # prefix tokens reused from the index at admit
    committed_pages: int = 0      # commit pointer: page_ids[:k] are in the index
    commit_stalled: bool = False  # first-writer-wins conflict: pointer is final


@dataclasses.dataclass
class _Node:
    """One committed (index-resident) page."""
    pid: int
    key: PageKey
    parent: int                   # parent pid, -1 at the root
    children: int = 0             # committed children (reclaim leaves first)
    refs: int = 0                 # owners holding this page
    last_used: int = 0            # LRU clock tick of the last match/commit
    chain_hash: bytes = b""       # cross-engine content name (page_chain_hash)


class BlockAllocator:
    def __init__(self, capacity_tokens: int, block_size: int = 16):
        assert capacity_tokens > 0 and block_size > 0
        self.block_size = block_size
        self.num_blocks = capacity_tokens // block_size
        # LIFO free list of physical page ids (reuse-hot pages first)
        self._free_ids: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self.owners: Dict[int, _Owner] = {}
        self.evictions = 0            # lifetime eviction count (KV pressure)
        self.peak_used_blocks = 0     # high-water mark (per-shard accounting)
        # ---- prefix-cache state ---------------------------------------------
        self._nodes: Dict[int, _Node] = {}          # pid -> committed page
        self._index: Dict[PageKey, int] = {}        # content chain -> pid
        # refcount-0 committed pages in insertion (≈LRU) order
        self._cached: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        self._clock = 0
        self.cache_commits = 0        # lifetime pages frozen into the index
        self.cache_hit_tokens = 0     # lifetime tokens served from the index
        self.cache_reclaimed = 0      # lifetime cached pages reclaimed (tier 1)
        # Optional commit/reclaim observer (``on_commit(chain_hash, depth)`` /
        # ``on_reclaim(chain_hash)``): the cross-engine prefix directory
        # mirrors this allocator's index through these notifications.
        self.listener = None

    # ---- queries --------------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        """Pages obtainable without evicting a live owner: the free list plus
        refcount-0 cached pages (reclaimable, tier-1 eviction)."""
        return len(self._free_ids) + len(self._cached)

    @property
    def cached_blocks(self) -> int:
        """Refcount-0 committed pages (reclaimable prefix cache)."""
        return len(self._cached)

    @property
    def live_blocks(self) -> int:
        """Pages held by at least one live owner."""
        return self.num_blocks - self.free_blocks

    def blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    def can_admit(self, prompt_len: int, reserve_tokens: int = 0) -> bool:
        return self.blocks_for(prompt_len + reserve_tokens) <= self.free_blocks

    def used_tokens(self) -> int:
        return sum(o.tokens for o in self.owners.values())

    def free_tokens(self) -> int:
        """Upper bound on new tokens storable without eviction (whole free +
        reclaimable pages plus the tail slack of each owner's last page)."""
        slack = sum(o.blocks * self.block_size - o.tokens
                    for o in self.owners.values())
        return self.free_blocks * self.block_size + slack

    def utilization(self) -> float:
        return 1.0 - self.free_blocks / self.num_blocks

    def page_table(self, rid: int) -> List[int]:
        """Physical page ids backing ``rid`` in logical order."""
        return list(self.owners[rid].page_ids)

    def cached_tokens(self, rid: int) -> int:
        """Prefix tokens ``rid`` reused from the index at admission."""
        return self.owners[rid].cached_tokens

    def committed_count(self, rid: int) -> int:
        """How many of ``rid``'s leading pages are frozen in the index."""
        return self.owners[rid].committed_pages

    def commit_stalled(self, rid: int) -> bool:
        """True once ``rid``'s commit pointer hit a first-writer-wins
        conflict — further ``commit`` calls cannot advance it, so callers
        should stop re-deriving content for this owner."""
        return self.owners[rid].commit_stalled

    def referenced_committed_blocks(self) -> int:
        """Committed pages held by at least one live owner (each holds
        exactly ``block_size`` written tokens, counted once however many
        owners share it)."""
        return len(self._nodes) - len(self._cached)

    def shard_stats(self, num_shards: int = 1) -> Dict:
        """Per-shard page-pool accounting for the sharded serving executor.

        A head-sharded pool stores every page id on every shard but only
        ``1/num_shards`` of each page's bytes (KV heads are the sharded dim),
        so page *counts* replicate across shards while byte capacity divides;
        ``num_shards=1`` also covers the replicated sequence-sharded
        fallback. The peak high-water mark feeds the per-shard allocator
        imbalance follow-on (ROADMAP)."""
        used = self.num_blocks - self.free_blocks
        return {
            "kv_pool_shards": num_shards,
            "pages_total": self.num_blocks,
            "pages_used": used,
            "pages_free": self.free_blocks,
            "pages_cached": self.cached_blocks,
            "peak_pages_used": self.peak_used_blocks,
            "utilization": self.utilization(),
            "tokens_capacity_per_shard": self.num_blocks * self.block_size,
        }

    def cache_stats(self) -> Dict:
        """Prefix-cache accounting (BENCH_goodput.json record)."""
        return {
            "cached_pages": self.cached_blocks,
            "committed_pages": len(self._nodes),
            "cache_commits": self.cache_commits,
            "cache_hit_tokens": self.cache_hit_tokens,
            "cache_reclaimed_pages": self.cache_reclaimed,
        }

    def _note_usage(self) -> None:
        self.peak_used_blocks = max(self.peak_used_blocks,
                                    self.num_blocks - self.free_blocks)

    # ---- prefix cache: match / commit / reclaim --------------------------------
    def _page_chunks(self, token_ids: Sequence[int], n_pages: int):
        ps = self.block_size
        for k in range(n_pages):
            yield tuple(int(t) for t in token_ids[k * ps:(k + 1) * ps])

    def match_prefix(self, token_ids: Sequence[int],
                     max_tokens: Optional[int] = None
                     ) -> Tuple[List[int], int]:
        """Longest frozen prefix of ``token_ids`` in the index, as
        ``(page_ids, matched_len)``. Pure query — no refcounts move (admit
        with the same ids to actually take the pages). ``max_tokens`` caps
        the match (the engine passes ``prompt_len - 1`` so at least one
        prompt token is always computed to produce first-token logits);
        matches are whole-page granular."""
        limit = len(token_ids) if max_tokens is None else min(
            max_tokens, len(token_ids))
        n_pages = limit // self.block_size
        out: List[int] = []
        parent = -1
        for chunk in self._page_chunks(token_ids, n_pages):
            pid = self._index.get((parent, chunk))
            if pid is None:
                break
            out.append(pid)
            parent = pid
        return out, len(out) * self.block_size

    def _incref(self, pid: int) -> None:
        node = self._nodes[pid]
        if node.refs == 0:
            self._cached.pop(pid, None)
        node.refs += 1
        self._clock += 1
        node.last_used = self._clock

    def _decref(self, pid: int) -> None:
        node = self._nodes.get(pid)
        if node is None:
            self._free_ids.append(pid)
            return
        node.refs -= 1
        assert node.refs >= 0, f"refcount underflow on page {pid}"
        if node.refs == 0:
            self._cached[pid] = None      # newest at the end (LRU order)

    def _reclaim_one(self) -> Optional[int]:
        """Tier-1 eviction: drop the least-recently-used cached *leaf* page
        from the index and return its id. Leaves first keeps every surviving
        chain matchable from the root; a page with live-ref children cannot
        be cached itself (an owner holding a child holds its whole prefix),
        so scanning ``_cached`` for ``children == 0`` always succeeds when
        the pool is non-empty."""
        for pid in self._cached:
            node = self._nodes[pid]
            if node.children == 0:
                del self._cached[pid]
                del self._nodes[pid]
                self._index.pop(node.key, None)
                parent = self._nodes.get(node.parent)
                if parent is not None:
                    parent.children -= 1
                self.cache_reclaimed += 1
                if self.listener is not None:
                    self.listener.on_reclaim(node.chain_hash)
                return pid
        return None

    def _alloc_pages(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` physical pages: free list first, then reclaim cached
        pages (LRU leaves). Returns None (taking nothing) if even the cache
        cannot cover the request."""
        if n > len(self._free_ids) + len(self._cached):
            return None
        out: List[int] = []
        for _ in range(n):
            if self._free_ids:
                out.append(self._free_ids.pop())
            else:
                pid = self._reclaim_one()
                assert pid is not None, "cached pool scan failed"
                out.append(pid)
        return out

    def commit(self, rid: int, content_ids: Sequence[int],
               upto_tokens: int) -> int:
        """Freeze ``rid``'s fully-written leading pages into the index.

        ``content_ids[:upto_tokens]`` is the token content of the owner's
        cache (prompt, plus emitted tokens for decode pages); only whole
        pages are committed, continuing from the owner's commit pointer.
        A chain position whose key already names a *different* physical page
        (an identical prompt prefilled concurrently) stays uncommitted —
        first writer wins and the pointer stalls there, which only costs a
        missed future match. Returns pages newly committed."""
        o = self.owners[rid]
        full = min(upto_tokens, len(content_ids)) // self.block_size
        done = 0
        ps = self.block_size
        while o.committed_pages < full:
            k = o.committed_pages
            pid = o.page_ids[k]
            parent = o.page_ids[k - 1] if k > 0 else -1
            if parent != -1 and parent not in self._nodes:
                o.commit_stalled = True    # chain broken by an earlier stall
                break
            if pid in self._nodes:         # already frozen (matched page)
                o.committed_pages += 1
                continue
            chunk = tuple(int(t) for t in content_ids[k * ps:(k + 1) * ps])
            key: PageKey = (parent, chunk)
            if key in self._index:
                o.commit_stalled = True    # duplicate content, first wins
                break
            self._clock += 1
            parent_chain = (ROOT_CHAIN if parent == -1
                            else self._nodes[parent].chain_hash)
            self._nodes[pid] = _Node(pid, key, parent, refs=1,
                                     last_used=self._clock,
                                     chain_hash=page_chain_hash(parent_chain,
                                                                chunk))
            self._index[key] = pid
            if parent != -1:
                self._nodes[parent].children += 1
            o.committed_pages += 1
            self.cache_commits += 1
            done += 1
            if self.listener is not None:
                self.listener.on_commit(self._nodes[pid].chain_hash, k + 1)
        return done

    # ---- lifecycle --------------------------------------------------------------
    def admit(self, rid: int, initial_tokens: int = 0,
              token_ids: Optional[Sequence[int]] = None,
              match_limit: Optional[int] = None) -> bool:
        """Reserve ``initial_tokens`` for ``rid``. With ``token_ids`` the
        prefix cache is consulted first: matched frozen pages are reused
        (increfed) and only the remainder is allocated fresh; read the hit
        back via ``cached_tokens(rid)``. Without ids the legacy counting
        behaviour is exact (the analytic simulator's path)."""
        assert rid not in self.owners, f"double admit {rid}"
        matched: List[int] = []
        if token_ids is not None and initial_tokens > 0:
            matched, _ = self.match_prefix(token_ids, max_tokens=match_limit)
        total = self.blocks_for(initial_tokens) if initial_tokens else 0
        matched = matched[:total]
        need = total - len(matched)
        # matched cached pages leave the reclaimable pool on incref, so they
        # cannot double as supply for the fresh remainder
        supply = len(self._free_ids) + len(self._cached) \
            - sum(1 for pid in matched if self._nodes[pid].refs == 0)
        if need > supply:
            return False
        for pid in matched:
            self._incref(pid)
        fresh = self._alloc_pages(need)
        assert fresh is not None, "supply check out of sync"
        cached_tok = len(matched) * self.block_size
        self.owners[rid] = _Owner(rid, total, initial_tokens,
                                  matched + fresh,
                                  cached_tokens=cached_tok,
                                  committed_pages=len(matched))
        self.cache_hit_tokens += cached_tok
        self._note_usage()
        return True

    def grow(self, rid: int, new_tokens: int) -> bool:
        """Extend rid's allocation to cover ``new_tokens`` total tokens."""
        o = self.owners[rid]
        if new_tokens <= o.tokens:
            return True
        need = self.blocks_for(new_tokens) - o.blocks
        if need > 0:
            fresh = self._alloc_pages(need)
            if fresh is None:
                return False
            o.page_ids.extend(fresh)
            o.blocks += need
        o.tokens = new_tokens
        self._note_usage()
        return True

    def free(self, rid: int) -> None:
        """Release ``rid``'s hold: committed pages are decrefed (surviving
        as reclaimable cache when no other owner holds them), private
        uncommitted pages return to the free list."""
        o = self.owners.pop(rid, None)
        if o is not None:
            for pid in o.page_ids:
                self._decref(pid)

    # ---- preemption policy ------------------------------------------------------
    def pick_victim(self, needy_rid: int,
                    priority: Callable[[int], float],
                    eligible: Optional[Callable[[int], bool]] = None
                    ) -> Optional[int]:
        """Lowest-priority owner (largest ``priority(rid)`` key) other than
        the needy request — the shared evict-and-recompute policy (tier-2
        eviction; refcount-0 cached pages are always reclaimed first by
        ``grow``/``admit``). Callers pass e.g. ``priority=arrival_of`` so the
        newest request is relegated first (vLLM recompute order).
        ``eligible`` filters the candidate set (the engine's SLO-class
        guard: a victim of a more latency-critical class than the needy
        request is never relegated — e.g. ``batch`` growth cannot evict
        ``interactive``)."""
        cands = [rid for rid in self.owners
                 if rid != needy_rid and (eligible is None or eligible(rid))]
        if not cands:
            return None
        return max(cands, key=priority)

    def evict(self, rid: int) -> None:
        """Release a victim's hold and count the eviction. Pages shared with
        another live owner are merely decrefed — a live ref is never
        touched, only the victim's *exclusive* pages become reclaimable."""
        assert rid in self.owners, f"evicting non-owner {rid}"
        self.free(rid)
        self.evictions += 1

    # ---- invariants (property-tested) -------------------------------------------
    def check_invariants(self) -> None:
        held = {pid for o in self.owners.values() for pid in o.page_ids}
        free = set(self._free_ids)
        cached = set(self._cached)
        assert len(free) == len(self._free_ids), "free id duplicated"
        assert not free & held, "page both free and owned"
        assert not free & cached, "page both free and cached"
        assert not cached & held, "cached page still owned"
        assert free | cached | held == set(range(self.num_blocks)), \
            "page leak"
        assert all(len(o.page_ids) == o.blocks for o in self.owners.values()), \
            "owner id/block mismatch"
        for o in self.owners.values():
            assert o.blocks * self.block_size >= o.tokens, \
                "owner under-allocated"
            assert o.committed_pages <= o.blocks
            assert all(pid in self._nodes
                       for pid in o.page_ids[:o.committed_pages]), \
                "commit pointer past an unfrozen page"
        # refcounts are exactly the number of owners holding each page;
        # uncommitted pages are exclusively owned
        hold_counts: Dict[int, int] = {}
        for o in self.owners.values():
            for pid in o.page_ids:
                hold_counts[pid] = hold_counts.get(pid, 0) + 1
        for pid, n in hold_counts.items():
            node = self._nodes.get(pid)
            if node is None:
                assert n == 1, f"uncommitted page {pid} shared by {n} owners"
            else:
                assert node.refs == n, (pid, node.refs, n)
        assert cached == {pid for pid, nd in self._nodes.items()
                          if nd.refs == 0}, "cached pool / refcount drift"
        # index <-> nodes bijection, child counts consistent
        assert set(self._index.values()) == set(self._nodes), "index drift"
        assert all(self._nodes[pid].key in self._index
                   for pid in self._nodes), "node missing from index"
        kids: Dict[int, int] = {}
        for nd in self._nodes.values():
            if nd.parent != -1:
                assert nd.parent in self._nodes, "orphaned committed child"
                kids[nd.parent] = kids.get(nd.parent, 0) + 1
        for pid, nd in self._nodes.items():
            assert nd.children == kids.get(pid, 0), (pid, nd.children)
