"""Paged KV-cache block allocator (vLLM-style, §4 substrate).

Token storage is paged into fixed-size blocks; requests own block lists that
grow as prefill/decode advances. The allocator is the serving engine's and
simulator's admission/ preemption authority: a request is admitted only when
its full prompt plus a decode reserve fits, and decode growth failures trigger
eviction of the lowest-priority owner (recompute-on-resume policy).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class _Owner:
    rid: int
    blocks: int
    tokens: int


class BlockAllocator:
    def __init__(self, capacity_tokens: int, block_size: int = 16):
        assert capacity_tokens > 0 and block_size > 0
        self.block_size = block_size
        self.num_blocks = capacity_tokens // block_size
        self.free_blocks = self.num_blocks
        self.owners: Dict[int, _Owner] = {}

    # ---- queries --------------------------------------------------------------
    def blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    def can_admit(self, prompt_len: int, reserve_tokens: int = 0) -> bool:
        return self.blocks_for(prompt_len + reserve_tokens) <= self.free_blocks

    def used_tokens(self) -> int:
        return sum(o.tokens for o in self.owners.values())

    def utilization(self) -> float:
        return 1.0 - self.free_blocks / self.num_blocks

    # ---- lifecycle --------------------------------------------------------------
    def admit(self, rid: int, initial_tokens: int = 0) -> bool:
        assert rid not in self.owners, f"double admit {rid}"
        need = self.blocks_for(initial_tokens) if initial_tokens else 0
        if need > self.free_blocks:
            return False
        self.owners[rid] = _Owner(rid, need, initial_tokens)
        self.free_blocks -= need
        return True

    def grow(self, rid: int, new_tokens: int) -> bool:
        """Extend rid's allocation to cover ``new_tokens`` total tokens."""
        o = self.owners[rid]
        if new_tokens <= o.tokens:
            return True
        need = self.blocks_for(new_tokens) - o.blocks
        if need > self.free_blocks:
            return False
        o.blocks += need
        o.tokens = new_tokens
        self.free_blocks -= need
        return True

    def free(self, rid: int) -> None:
        o = self.owners.pop(rid, None)
        if o is not None:
            self.free_blocks += o.blocks

    # ---- invariants (property-tested) -------------------------------------------
    def check_invariants(self) -> None:
        used = sum(o.blocks for o in self.owners.values())
        assert used + self.free_blocks == self.num_blocks, "block leak"
        assert self.free_blocks >= 0, "overcommit"
        for o in self.owners.values():
            assert o.blocks * self.block_size >= o.tokens, "owner under-allocated"
