"""Paged KV-cache block allocator (vLLM-style, §4 substrate).

Token storage is paged into fixed-size blocks; requests own block lists that
grow as prefill/decode advances. The allocator is the *single* admission /
preemption authority shared by the real ``ServingEngine`` and the analytic
``ServingSimulator``: a request is admitted only when its full prompt plus a
decode reserve fits, growth happens per emitted/prefilled token, and decode
growth failures trigger eviction of the lowest-priority owner
(recompute-on-resume policy, ``pick_victim``).

Beyond pure accounting the allocator hands out *physical page ids* from a
free list; the engine turns an owner's ``page_ids`` into the block table rows
the paged attention kernels consume. The analytic simulator ignores the ids
and uses only the counting API — both views are kept consistent by
``check_invariants``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class _Owner:
    rid: int
    blocks: int
    tokens: int
    page_ids: List[int] = dataclasses.field(default_factory=list)


class BlockAllocator:
    def __init__(self, capacity_tokens: int, block_size: int = 16):
        assert capacity_tokens > 0 and block_size > 0
        self.block_size = block_size
        self.num_blocks = capacity_tokens // block_size
        self.free_blocks = self.num_blocks
        # LIFO free list of physical page ids (reuse-hot pages first)
        self._free_ids: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self.owners: Dict[int, _Owner] = {}
        self.evictions = 0            # lifetime eviction count (KV pressure)
        self.peak_used_blocks = 0     # high-water mark (per-shard accounting)

    # ---- queries --------------------------------------------------------------
    def blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    def can_admit(self, prompt_len: int, reserve_tokens: int = 0) -> bool:
        return self.blocks_for(prompt_len + reserve_tokens) <= self.free_blocks

    def used_tokens(self) -> int:
        return sum(o.tokens for o in self.owners.values())

    def free_tokens(self) -> int:
        """Upper bound on new tokens storable without eviction (whole free
        pages plus the tail slack of each owner's last page)."""
        slack = sum(o.blocks * self.block_size - o.tokens
                    for o in self.owners.values())
        return self.free_blocks * self.block_size + slack

    def utilization(self) -> float:
        return 1.0 - self.free_blocks / self.num_blocks

    def page_table(self, rid: int) -> List[int]:
        """Physical page ids backing ``rid`` in logical order."""
        return list(self.owners[rid].page_ids)

    def shard_stats(self, num_shards: int = 1) -> Dict:
        """Per-shard page-pool accounting for the sharded serving executor.

        A head-sharded pool stores every page id on every shard but only
        ``1/num_shards`` of each page's bytes (KV heads are the sharded dim),
        so page *counts* replicate across shards while byte capacity divides;
        ``num_shards=1`` also covers the replicated sequence-sharded
        fallback. The peak high-water mark feeds the per-shard allocator
        imbalance follow-on (ROADMAP)."""
        used = self.num_blocks - self.free_blocks
        return {
            "kv_pool_shards": num_shards,
            "pages_total": self.num_blocks,
            "pages_used": used,
            "pages_free": self.free_blocks,
            "peak_pages_used": self.peak_used_blocks,
            "utilization": self.utilization(),
            "tokens_capacity_per_shard": self.num_blocks * self.block_size,
        }

    def _note_usage(self) -> None:
        self.peak_used_blocks = max(self.peak_used_blocks,
                                    self.num_blocks - self.free_blocks)

    # ---- lifecycle --------------------------------------------------------------
    def admit(self, rid: int, initial_tokens: int = 0) -> bool:
        assert rid not in self.owners, f"double admit {rid}"
        need = self.blocks_for(initial_tokens) if initial_tokens else 0
        if need > self.free_blocks:
            return False
        ids = [self._free_ids.pop() for _ in range(need)]
        self.owners[rid] = _Owner(rid, need, initial_tokens, ids)
        self.free_blocks -= need
        self._note_usage()
        return True

    def grow(self, rid: int, new_tokens: int) -> bool:
        """Extend rid's allocation to cover ``new_tokens`` total tokens."""
        o = self.owners[rid]
        if new_tokens <= o.tokens:
            return True
        need = self.blocks_for(new_tokens) - o.blocks
        if need > self.free_blocks:
            return False
        o.page_ids.extend(self._free_ids.pop() for _ in range(need))
        o.blocks += need
        o.tokens = new_tokens
        self.free_blocks -= need
        self._note_usage()
        return True

    def free(self, rid: int) -> None:
        o = self.owners.pop(rid, None)
        if o is not None:
            self.free_blocks += o.blocks
            self._free_ids.extend(reversed(o.page_ids))

    # ---- preemption policy ------------------------------------------------------
    def pick_victim(self, needy_rid: int,
                    priority: Callable[[int], float],
                    eligible: Optional[Callable[[int], bool]] = None
                    ) -> Optional[int]:
        """Lowest-priority owner (largest ``priority(rid)`` key) other than
        the needy request — the shared evict-and-recompute policy. Callers
        pass e.g. ``priority=arrival_of`` so the newest request is relegated
        first (vLLM recompute order). ``eligible`` filters the candidate set
        (the engine's SLO-class guard: a victim of a more latency-critical
        class than the needy request is never relegated — e.g. ``batch``
        growth cannot evict ``interactive``)."""
        cands = [rid for rid in self.owners
                 if rid != needy_rid and (eligible is None or eligible(rid))]
        if not cands:
            return None
        return max(cands, key=priority)

    def evict(self, rid: int) -> None:
        """Free a victim's pages and count the eviction."""
        assert rid in self.owners, f"evicting non-owner {rid}"
        self.free(rid)
        self.evictions += 1

    # ---- invariants (property-tested) -------------------------------------------
    def check_invariants(self) -> None:
        used = sum(o.blocks for o in self.owners.values())
        assert used + self.free_blocks == self.num_blocks, "block leak"
        assert self.free_blocks >= 0, "overcommit"
        assert len(self._free_ids) == self.free_blocks, "id-list drift"
        held = [pid for o in self.owners.values() for pid in o.page_ids]
        assert all(len(o.page_ids) == o.blocks for o in self.owners.values()), \
            "owner id/block mismatch"
        assert len(set(held)) == len(held), "page double-owned"
        assert not (set(held) & set(self._free_ids)), "page both free and owned"
        for o in self.owners.values():
            assert o.blocks * self.block_size >= o.tokens, "owner under-allocated"
