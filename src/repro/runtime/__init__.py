"""Distributed runtime: fault tolerance, elasticity, gradient compression."""
