"""Elastic re-mesh planning: map a checkpoint onto a different device count.

When nodes are lost (or added) the job restarts on N' != N devices. The
planner picks a new (data, model) factorization — preserving the model-axis
width when possible so tensor-parallel shards stay aligned — and the restore
path re-places every leaf with the new NamedSharding (checkpoint leaves are
stored unsharded per host, so re-placement is just device_put with the new
spec; see ``repro.train.checkpoint.restore``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    def describe(self) -> str:
        return " x ".join(f"{a}={s}" for a, s in zip(self.axes, self.shape))


def plan_remesh(num_devices: int, prefer_model: int = 16,
                multi_pod_at: int = 512, pod_size: int = 256) -> MeshPlan:
    """Choose a mesh factorization for an elastic restart.

    Keeps the model axis at ``prefer_model`` when it divides the device
    count (TP shards unchanged -> no weight resharding traffic); otherwise
    falls back to the largest power-of-two divisor <= prefer_model.
    """
    assert num_devices >= 1
    if num_devices >= multi_pod_at and num_devices % pod_size == 0:
        pods = num_devices // pod_size
        inner = plan_remesh(pod_size, prefer_model, multi_pod_at=1 << 62)
        return MeshPlan((pods,) + inner.shape, ("pod",) + inner.axes)
    model = prefer_model
    while model > 1 and num_devices % model:
        model //= 2
    data = num_devices // model
    return MeshPlan((data, model), ("data", "model"))


def build_mesh(plan: MeshPlan):
    return jax.make_mesh(plan.shape, plan.axes)


def resharding_plan(old: MeshPlan, new: MeshPlan) -> dict:
    """Human/log-facing summary of what an elastic transition moves."""
    old_model = old.shape[old.axes.index("model")] if "model" in old.axes else 1
    new_model = new.shape[new.axes.index("model")] if "model" in new.axes else 1
    return {
        "model_axis_preserved": old_model == new_model,
        "tp_reshard_required": old_model != new_model,
        "old": old.describe(),
        "new": new.describe(),
    }
