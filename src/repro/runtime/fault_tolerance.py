"""Fault tolerance for 1000+-node runs: heartbeats, checkpoint/restart,
straggler detection & mitigation.

Design (matching the scale posture in DESIGN.md §6):

* ``HeartbeatMonitor`` — per-worker liveness with a deadline; a missed
  heartbeat marks the worker dead and triggers the supervisor's restart path.
* ``StragglerDetector`` — per-step worker durations; a worker consistently
  slower than ``threshold`` x median over a window is *relegated* (the same
  relegation philosophy the paper's scheduler applies to SLO-expired
  requests: capacity is protected for the healthy majority).
* ``TrainingSupervisor`` — drives a step function with periodic async
  checkpoints; on failure, restores the latest checkpoint and replays. The
  harness is deliberately transport-agnostic (in this repo workers are
  simulated; on a real cluster the callbacks map to jax.distributed +
  coordinator liveness).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.train import checkpoint


@dataclasses.dataclass
class WorkerState:
    last_heartbeat: float
    alive: bool = True
    relegated: bool = False


class HeartbeatMonitor:
    def __init__(self, workers: List[str], timeout: float = 60.0):
        self.timeout = timeout
        now = time.monotonic()
        self.workers: Dict[str, WorkerState] = {
            w: WorkerState(last_heartbeat=now) for w in workers}

    def beat(self, worker: str, now: Optional[float] = None) -> None:
        self.workers[worker].last_heartbeat = now or time.monotonic()

    def check(self, now: Optional[float] = None) -> List[str]:
        """Returns newly-dead workers."""
        now = now or time.monotonic()
        dead = []
        for name, st in self.workers.items():
            if st.alive and now - st.last_heartbeat > self.timeout:
                st.alive = False
                dead.append(name)
        return dead

    def alive_count(self) -> int:
        return sum(1 for s in self.workers.values() if s.alive and not s.relegated)


class StragglerDetector:
    def __init__(self, workers: List[str], window: int = 20,
                 threshold: float = 1.5, min_samples: int = 5):
        self.window = window
        self.threshold = threshold
        self.min_samples = min_samples
        self.durations: Dict[str, collections.deque] = {
            w: collections.deque(maxlen=window) for w in workers}

    def record(self, worker: str, duration: float) -> None:
        self.durations[worker].append(duration)

    def stragglers(self) -> List[str]:
        means = {w: sum(d) / len(d) for w, d in self.durations.items()
                 if len(d) >= self.min_samples}
        if len(means) < 2:
            return []
        med = sorted(means.values())[len(means) // 2]
        return [w for w, m in means.items() if m > self.threshold * med]


class TrainingSupervisor:
    """Checkpoint/restart loop around an arbitrary step function."""

    def __init__(self, ckpt_dir: str, save_every: int = 50,
                 async_save: bool = True):
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.async_save = async_save
        self._pending = None

    def run(self, step_fn: Callable, state, start_step: int, num_steps: int,
            fail_at: Optional[Callable[[int], bool]] = None,
            on_restore=None) -> tuple:
        """Runs steps with periodic checkpoints; simulated failures via
        ``fail_at(step)`` raise and exercise the restore path. Returns
        (state, completed_step, num_restarts)."""
        step = start_step
        restarts = 0
        while step < num_steps:
            try:
                if fail_at is not None and fail_at(step):
                    raise RuntimeError(f"injected failure at step {step}")
                state = step_fn(state, step)
                step += 1
                if step % self.save_every == 0:
                    self.wait()
                    self._pending = checkpoint.save(
                        self.ckpt_dir, step, state, async_save=self.async_save)
            except RuntimeError:
                restarts += 1
                self.wait()
                last = checkpoint.latest_step(self.ckpt_dir)
                if last is None:
                    step = start_step
                    if on_restore is not None:
                        state = on_restore(None, start_step)
                    continue
                state = checkpoint.restore(self.ckpt_dir, last, state)
                if on_restore is not None:
                    state = on_restore(state, last)
                step = last
        self.wait()
        return state, step, restarts

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
