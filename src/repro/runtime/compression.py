"""Int8 gradient compression with error feedback (cross-pod hop).

At 512+ chips the pod-crossing gradient reduce rides DCN, not ICI; int8
block-quantization cuts that traffic 4x vs fp32 (2x vs bf16). Error feedback
(residual accumulation) keeps SGD/Adam convergence: the quantization error of
step t is added back into the gradient of step t+1, so the *accumulated*
update is unbiased.

All jittable; the compressed representation is (int8 values, fp32 per-block
scales) so it can be fed directly to an all-reduce/all-gather over the pod
axis.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    n = x.size
    pad = (-n) % mult
    return jnp.pad(x.reshape(-1), (0, pad))


def compress_leaf(g: jnp.ndarray, ef: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q int8 [n_blocks, BLOCK], scales fp32 [n_blocks], new_ef)."""
    gf = g.astype(jnp.float32) + ef
    flat = _pad_to(gf, BLOCK).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale[:, None]), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[: g.size].reshape(g.shape)
    new_ef = gf - deq
    return q, scale, new_ef


def decompress_leaf(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype) -> jnp.ndarray:
    deq = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return deq[:n].reshape(shape).astype(dtype)


def init_error_feedback(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree(grads, ef_state):
    """Round-trips every leaf through int8; returns (decompressed grads,
    new error-feedback state). This models the cross-pod hop numerically —
    the launcher applies it to the grads before the pod-axis reduction."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_ef = treedef.flatten_up_to(ef_state)
    outs = []
    new_efs = []
    for g, ef in zip(flat_g, flat_ef):
        q, scale, new_ef = compress_leaf(g, ef)
        outs.append(decompress_leaf(q, scale, g.shape, g.dtype))
        new_efs.append(new_ef)
    return treedef.unflatten(outs), treedef.unflatten(new_efs)
