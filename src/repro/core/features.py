"""Batch feature construction (paper §3.2, Table 1).

A batch is ``[(c_i, u_i)]``: tokens scheduled this round and tokens already
cached, per request. Requests split into decode (c_i <= 1) and prefill
(c_i > 1) sets (Eq. 2); the scene label (Eq. 3) selects the expert model.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

SCENES = ("pure_decode", "pure_prefill", "mixed")
NUM_FEATURES = 7


def split_sets(batch: Sequence[Tuple[int, int]]):
    """Eq. 2: D = {i | c_i <= 1}, P = {i | c_i > 1}."""
    D = [(c, u) for c, u in batch if c <= 1]
    P = [(c, u) for c, u in batch if c > 1]
    return D, P


def scene_of(batch: Sequence[Tuple[int, int]]) -> str:
    """Eq. 3."""
    D, P = split_sets(batch)
    if not P:
        return "pure_decode"
    if not D:
        return "pure_prefill"
    return "mixed"


def batch_features(batch: Sequence[Tuple[int, int]]) -> np.ndarray:
    """Table 1's 7-dim feature vector x."""
    D, P = split_sets(batch)
    x1 = float(sum(c * (u + c) for c, u in P))   # prefill attention complexity
    x2 = float(sum(c * c for c, u in P))          # chunk self-attention
    x3 = float(sum(u for _, u in batch))          # total cached tokens
    x4 = float(len(D))                            # decode request count
    x5 = float(sum(u for _, u in D))              # decode cumulative context
    x6 = float(sum(c for c, _ in P))              # total prefill tokens
    x7 = float(max((c for c, _ in P), default=0))  # max single prefill chunk
    return np.array([x1, x2, x3, x4, x5, x6, x7], dtype=np.float64)


def featurize(batch: Sequence[Tuple[int, int]]) -> Tuple[np.ndarray, str]:
    return batch_features(batch), scene_of(batch)


def features_many(batches: Sequence[Sequence[Tuple[int, int]]]):
    """Vectorized ``featurize`` over many batches.

    Returns ``(X [N, NUM_FEATURES], scenes [N], csum [N])`` where ``csum`` is
    each batch's total scheduled tokens (the cold-start predictor input).
    Segment reductions (``bincount`` / ``maximum.at``) over the flattened
    (c, u) pairs replace N python-level ``batch_features`` calls."""
    n = len(batches)
    X = np.zeros((n, NUM_FEATURES), dtype=np.float64)
    scenes = np.full(n, "pure_decode", dtype=object)
    csum = np.zeros(n, dtype=np.float64)
    flat = [cu for b in batches for cu in b]
    if not flat:
        return X, scenes, csum
    seg = np.repeat(np.arange(n), [len(b) for b in batches])
    cu = np.asarray(flat, dtype=np.float64)
    c, u = cu[:, 0], cu[:, 1]
    P = c > 1
    D = ~P
    X[:, 0] = np.bincount(seg[P], weights=(c * (u + c))[P], minlength=n)
    X[:, 1] = np.bincount(seg[P], weights=(c * c)[P], minlength=n)
    X[:, 2] = np.bincount(seg, weights=u, minlength=n)
    X[:, 3] = np.bincount(seg[D], minlength=n)
    X[:, 4] = np.bincount(seg[D], weights=u[D], minlength=n)
    X[:, 5] = np.bincount(seg[P], weights=c[P], minlength=n)
    np.maximum.at(X[:, 6], seg[P], c[P])
    has_p = np.bincount(seg[P], minlength=n) > 0
    has_d = np.bincount(seg[D], minlength=n) > 0
    scenes[has_p] = "pure_prefill"
    scenes[has_p & has_d] = "mixed"
    csum[:] = np.bincount(seg, weights=c, minlength=n)
    return X, scenes, csum
