"""Batch feature construction (paper §3.2, Table 1).

A batch is ``[(c_i, u_i)]`` or ``[(c_i, u_i, s_i)]``: tokens scheduled this
round, tokens already cached, and (optionally) speculative draft tokens
riding the row — a verify row of k drafts is ``(1 + k, u, k)``. Requests
split into decode (base width ``c_i - s_i <= 1``) and prefill sets (Eq. 2);
verify rows stay in the decode set — they are decode work that happens to be
k+1 tokens wide — and their extra cost is carried by feature x8 instead of
leaking into the prefill features. The scene label (Eq. 3) selects the
expert model.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

SCENES = ("pure_decode", "pure_prefill", "mixed")
NUM_FEATURES = 8


def _norm(e) -> Tuple[int, int, int]:
    """Entry -> (c, u, s); plain (c, u) pairs carry s = 0."""
    return (e[0], e[1], e[2] if len(e) > 2 else 0)


def split_sets(batch: Sequence[Tuple]):
    """Eq. 2 over base (non-speculative) widths:
    D = {i | c_i - s_i <= 1}, P = {i | c_i - s_i > 1}."""
    D, P = [], []
    for e in batch:
        c, u, s = _norm(e)
        (D if c - s <= 1 else P).append((c, u, s))
    return D, P


def scene_of(batch: Sequence[Tuple]) -> str:
    """Eq. 3."""
    D, P = split_sets(batch)
    if not P:
        return "pure_decode"
    if not D:
        return "pure_prefill"
    return "mixed"


def batch_features(batch: Sequence[Tuple]) -> np.ndarray:
    """Table 1's feature vector x, extended with x8 for speculation.

    x8 is the verify-row attention/compute mass ``sum_D (c-1) * (u + c)``:
    zero without speculation (every decode row has c = 1), and scaling with
    both draft count and context for verify rows — whose cost x1..x7 would
    otherwise record as a plain 1-token decode."""
    D, P = split_sets(batch)
    x1 = float(sum(c * (u + c) for c, u, _ in P))  # prefill attention complexity
    x2 = float(sum(c * c for c, u, _ in P))        # chunk self-attention
    x3 = float(sum(_norm(e)[1] for e in batch))    # total cached tokens
    x4 = float(len(D))                             # decode request count
    x5 = float(sum(u for _, u, _ in D))            # decode cumulative context
    x6 = float(sum(c for c, _, _ in P))            # total prefill tokens
    x7 = float(max((c for c, _, _ in P), default=0))  # max single prefill chunk
    x8 = float(sum((c - 1) * (u + c) for c, u, _ in D))  # verify-row mass
    return np.array([x1, x2, x3, x4, x5, x6, x7, x8], dtype=np.float64)


def featurize(batch: Sequence[Tuple]) -> Tuple[np.ndarray, str]:
    return batch_features(batch), scene_of(batch)


def features_many(batches: Sequence[Sequence[Tuple]]):
    """Vectorized ``featurize`` over many batches.

    Returns ``(X [N, NUM_FEATURES], scenes [N], csum [N])`` where ``csum`` is
    each batch's total scheduled tokens (the cold-start predictor input).
    Segment reductions (``bincount`` / ``maximum.at``) over the flattened
    (c, u, s) triples replace N python-level ``batch_features`` calls."""
    n = len(batches)
    X = np.zeros((n, NUM_FEATURES), dtype=np.float64)
    scenes = np.full(n, "pure_decode", dtype=object)
    csum = np.zeros(n, dtype=np.float64)
    flat = [cu for b in batches for cu in b]
    if not flat:
        return X, scenes, csum
    seg = np.repeat(np.arange(n), [len(b) for b in batches])
    widths = {len(e) for e in flat}
    if widths == {2}:
        pairs = np.asarray(flat, dtype=np.float64)
        cus = np.concatenate([pairs, np.zeros((len(flat), 1))], axis=1)
    elif widths == {3}:
        cus = np.asarray(flat, dtype=np.float64)
    else:   # mixed widths: normalize entry by entry
        cus = np.asarray([_norm(e) for e in flat], dtype=np.float64)
    c, u, s = cus[:, 0], cus[:, 1], cus[:, 2]
    P = (c - s) > 1
    D = ~P
    X[:, 0] = np.bincount(seg[P], weights=(c * (u + c))[P], minlength=n)
    X[:, 1] = np.bincount(seg[P], weights=(c * c)[P], minlength=n)
    X[:, 2] = np.bincount(seg, weights=u, minlength=n)
    X[:, 3] = np.bincount(seg[D], minlength=n)
    X[:, 4] = np.bincount(seg[D], weights=u[D], minlength=n)
    X[:, 5] = np.bincount(seg[P], weights=c[P], minlength=n)
    np.maximum.at(X[:, 6], seg[P], c[P])
    X[:, 7] = np.bincount(seg[D], weights=((c - 1) * (u + c))[D], minlength=n)
    has_p = np.bincount(seg[P], minlength=n) > 0
    has_d = np.bincount(seg[D], minlength=n) > 0
    scenes[has_p] = "pure_prefill"
    scenes[has_p & has_d] = "mixed"
    csum[:] = np.bincount(seg, weights=c, minlength=n)
    return X, scenes, csum
