"""Baseline schedulers the paper evaluates against (§5 Baselines).

* ``FCFSStaticScheduler`` — vLLM-style: static token budget, FCFS order.
* ``SarathiEDFScheduler`` — Sarathi chunked prefill with a static per-round
  token budget; candidates ordered earliest-TTFT-deadline-first.
* ``SingleStepGreedyScheduler`` — the §2.2 strawman: dynamic chunking that
  greedily maximizes the *current* iteration's budget under the tightest
  decode TBT slack (no look-ahead).
* ``QoServeLikeScheduler`` — a QoServe-style SOTA stand-in: single-step
  dynamic chunking + hybrid prioritization (deadline urgency blended with
  estimated remaining processing time) + proactive relegation of requests
  whose SLO already expired.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.scheduler import Decision, SchedulerBase
from repro.core.sliding_chunker import window_bounds
from repro.serving.request import Request


class FCFSStaticScheduler(SchedulerBase):
    name = "vllm-fcfs"

    def __init__(self, predictor=None, max_budget: int = 4096, chunk_budget: int = 512):
        super().__init__(predictor, max_budget)
        self.chunk_budget = chunk_budget

    def schedule(self, t, waiting, prefilling, decoding, kv=None):
        P = sorted(list(prefilling) + list(waiting), key=lambda r: r.arrival)
        budget = min(self.chunk_budget, self._budget_cap(decoding, kv))
        pred, alloc = self.F.forward(list(decoding), P, budget)
        if not alloc:
            return None
        return Decision(alloc, pred, budget, self.name)


class SarathiEDFScheduler(SchedulerBase):
    name = "sarathi-edf"

    def __init__(self, predictor=None, max_budget: int = 4096, chunk_budget: int = 512):
        super().__init__(predictor, max_budget)
        self.chunk_budget = chunk_budget

    def schedule(self, t, waiting, prefilling, decoding, kv=None):
        P = sorted(list(prefilling) + list(waiting), key=lambda r: r.ttft_deadline())
        budget = min(self.chunk_budget, self._budget_cap(decoding, kv))
        pred, alloc = self.F.forward(list(decoding), P, budget)
        if not alloc:
            return None
        return Decision(alloc, pred, budget, self.name)


class SingleStepGreedyScheduler(SchedulerBase):
    name = "single-step"

    def schedule(self, t, waiting, prefilling, decoding, kv=None):
        P = sorted(list(prefilling) + list(waiting), key=lambda r: r.ttft_deadline())
        D = list(decoding)
        t_cur, _ = window_bounds(D, t, default_cur=self.max_iter_time)
        t_cur = min(t_cur, self.max_iter_time)
        budget = min(self.F.time_to_budget(D, P, t_cur), self._budget_cap(D, kv))
        pred, alloc = self.F.forward(D, P, budget)
        if not alloc:
            return None
        return Decision(alloc, pred, budget, self.name)


class QoServeLikeScheduler(SchedulerBase):
    name = "qoserve"

    def __init__(self, predictor=None, max_budget: int = 4096, urgency_weight: float = 1.0,
                 max_iter_time: float = 0.05):
        super().__init__(predictor, max_budget, max_iter_time=max_iter_time)
        self.urgency_weight = urgency_weight

    def _key(self, r: Request, t: float):
        expired = 1 if r.ttft_slack(t) < 0 else 0
        est_time = r.remaining_prefill() / max(self.rho, 1.0)
        # hybrid: deadline urgency blended with estimated processing time
        score = r.ttft_slack(t) - self.urgency_weight * est_time
        return (expired, score, r.remaining_prefill())

    def schedule(self, t, waiting, prefilling, decoding, kv=None):
        P = sorted(list(prefilling) + list(waiting), key=lambda r: self._key(r, t))
        D = list(decoding)
        t_cur, _ = window_bounds(D, t, default_cur=self.max_iter_time)
        t_cur = min(t_cur, self.max_iter_time)
        budget = min(self.F.time_to_budget(D, P, t_cur), self._budget_cap(D, kv))
        pred, alloc = self.F.forward(D, P, budget)
        if not alloc:
            return None
        return Decision(alloc, pred, budget, self.name)


ALL_BASELINES = (FCFSStaticScheduler, SarathiEDFScheduler,
                 SingleStepGreedyScheduler, QoServeLikeScheduler)
