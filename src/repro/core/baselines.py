"""Baseline schedulers the paper evaluates against (§5 Baselines).

* ``FCFSStaticScheduler`` — vLLM-style: static token budget, FCFS order.
* ``SarathiEDFScheduler`` — Sarathi chunked prefill with a *TBT-calibrated*
  static token budget; candidates ordered earliest-TTFT-deadline-first.
  Sarathi-serve derives its fixed chunk size from the deployment's TBT
  target by offline profiling; mirroring that here (the largest pure-prefill
  chunk the predictor says fits the tightest TBT SLO present) replaced a
  hardcoded 512 that overshot the 40 ms dialogue TBT by ~70% per round —
  every decode token sharing a round with a full chunk missed its deadline,
  collapsing measured goodput to the QPS search bracket's lower edge on
  sharegpt/mixed-v1 (the BENCH_goodput.json ``sarathi-edf`` anomaly). Pass
  ``chunk_budget`` explicitly to pin the legacy fixed budget.
* ``SingleStepGreedyScheduler`` — the §2.2 strawman: dynamic chunking that
  greedily maximizes the *current* iteration's budget under the tightest
  decode TBT slack (no look-ahead).
* ``QoServeLikeScheduler`` — a QoServe-style SOTA stand-in: single-step
  dynamic chunking + hybrid prioritization (deadline urgency blended with
  estimated remaining processing time) + proactive relegation of requests
  whose SLO already expired.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.scheduler import Decision, SchedulerBase
from repro.core.sliding_chunker import window_bounds
from repro.serving.request import Request


class FCFSStaticScheduler(SchedulerBase):
    name = "vllm-fcfs"

    def __init__(self, predictor=None, max_budget: int = 4096, chunk_budget: int = 512):
        super().__init__(predictor, max_budget)
        self.chunk_budget = chunk_budget

    def schedule(self, t, waiting, prefilling, decoding, kv=None):
        P = sorted(list(prefilling) + list(waiting), key=lambda r: r.arrival)
        budget = min(self.chunk_budget, self._budget_cap(decoding, kv))
        pred, alloc = self.F.forward(list(decoding), P, budget)
        if not alloc:
            return None
        return Decision(alloc, pred, budget, self.name)


class SarathiEDFScheduler(SchedulerBase):
    name = "sarathi-edf"

    def __init__(self, predictor=None, max_budget: int = 4096,
                 chunk_budget: Optional[int] = None):
        super().__init__(predictor, max_budget)
        self.chunk_budget = chunk_budget

    def _derived_budget(self, tbt: float) -> int:
        """Sarathi-serve's offline TBT calibration, on the live predictor:
        the largest pure-prefill chunk whose predicted round time fits the
        TBT target. Like the real system's profiling, the canonical batch
        ignores the round's decode composition — under heavy decode load the
        fixed chunk still overshoots, which is exactly the behaviour
        SlidingServe's look-ahead improves on; unlike the slack-driven
        dynamic baselines, the target is the static SLO constant, not the
        current deadline gap."""
        lo, hi = 16, self.max_budget
        if self.predictor.predict([(hi, 0)]) <= tbt:
            return hi
        while hi - lo > 16:
            mid = (lo + hi) // 2
            if self.predictor.predict([(mid, 0)]) <= tbt:
                lo = mid
            else:
                hi = mid
        return lo

    def schedule(self, t, waiting, prefilling, decoding, kv=None):
        P = sorted(list(prefilling) + list(waiting), key=lambda r: r.ttft_deadline())
        D = list(decoding)
        if self.chunk_budget is not None:
            static = self.chunk_budget
        else:
            tbt = min((r.tbt_slo for r in D + P), default=None)
            if tbt is None:
                return None
            static = self._derived_budget(tbt)
        budget = min(static, self._budget_cap(D, kv))
        pred, alloc = self.F.forward(D, P, budget)
        if not alloc:
            return None
        return Decision(alloc, pred, budget, self.name)


class SingleStepGreedyScheduler(SchedulerBase):
    name = "single-step"

    def schedule(self, t, waiting, prefilling, decoding, kv=None):
        P = sorted(list(prefilling) + list(waiting), key=lambda r: r.ttft_deadline())
        D = list(decoding)
        t_cur, _ = window_bounds(D, t, default_cur=self.max_iter_time)
        t_cur = min(t_cur, self.max_iter_time)
        budget = min(self.F.time_to_budget(D, P, t_cur), self._budget_cap(D, kv))
        pred, alloc = self.F.forward(D, P, budget)
        if not alloc:
            return None
        return Decision(alloc, pred, budget, self.name)


class QoServeLikeScheduler(SchedulerBase):
    name = "qoserve"

    def __init__(self, predictor=None, max_budget: int = 4096, urgency_weight: float = 1.0,
                 max_iter_time: float = 0.05):
        super().__init__(predictor, max_budget, max_iter_time=max_iter_time)
        self.urgency_weight = urgency_weight

    def _key(self, r: Request, t: float):
        expired = 1 if r.ttft_slack(t) < 0 else 0
        est_time = r.remaining_prefill() / max(self.rho, 1.0)
        # hybrid: deadline urgency blended with estimated processing time
        score = r.ttft_slack(t) - self.urgency_weight * est_time
        return (expired, score, r.remaining_prefill())

    def schedule(self, t, waiting, prefilling, decoding, kv=None):
        P = sorted(list(prefilling) + list(waiting), key=lambda r: self._key(r, t))
        D = list(decoding)
        t_cur, _ = window_bounds(D, t, default_cur=self.max_iter_time)
        t_cur = min(t_cur, self.max_iter_time)
        budget = min(self.F.time_to_budget(D, P, t_cur), self._budget_cap(D, kv))
        pred, alloc = self.F.forward(D, P, budget)
        if not alloc:
            return None
        return Decision(alloc, pred, budget, self.name)


ALL_BASELINES = (FCFSStaticScheduler, SarathiEDFScheduler,
                 SingleStepGreedyScheduler, QoServeLikeScheduler)
