"""SlidingServe closed-loop scheduler (paper Fig. 3) + the Violation Checker.

Each round: (1) sort candidates with the Multi-Level Priority Sorter, (2)
build the *maximal candidate batch* under the server budget, (3) submit it to
the Violation Checker, (4) route to BatchConstructor (risk) or SlidingChunker
(no risk), (5) emit the executable batch (request-level token allocation).

``observe`` closes the loop: real batch latencies feed the online predictor
refit and the throughput estimate rho_t the sorter's urgency uses.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.batch_constructor import batch_constructor
from repro.core.forwarder import Alloc, BatchForwarder, DEFAULT_CLASS_SHARES
from repro.core.predictor import BatchLatencyPredictor
from repro.core.sliding_chunker import sliding_chunker, window_bounds
from repro.core.sorter import sort_candidates
from repro.serving.request import Request


@dataclasses.dataclass
class Decision:
    alloc: Alloc                      # [(request, tokens this round)]
    predicted_time: float
    budget: int
    route: str                        # "sliding" | "construct" | baseline name

    def batch(self) -> List[Tuple[int, int]]:
        return [(n, r.context_len()) for r, n in self.alloc]


@dataclasses.dataclass(frozen=True)
class KVPressure:
    """Paged-KV memory state the executor surfaces to the scheduler each
    round, so chunk budgets can back off before allocation failures force
    evict-and-recompute churn.

    ``free_tokens`` — new tokens storable without evicting a *live* request
    (free pages, owners' tail-page slack, and reclaimable cached pages).
    ``reclaimable_tokens`` — the prefix-cache slice of ``free_tokens``:
    refcount-0 frozen pages the allocator reclaims LRU-first before any live
    request is relegated. The split matters for backoff: ``utilization``
    counts only live-referenced tokens, so a pool whose idle capacity sits
    in reclaimable cached pages (a warm prefix cache) does not read as
    pressure. ``evictions`` — live-request evictions since the previous
    ``schedule`` call (not lifetime)."""

    utilization: float = 0.0
    free_tokens: int = 1 << 30
    evictions: int = 0
    reclaimable_tokens: int = 0


class SchedulerBase:
    """Common interface + shared observation machinery."""

    name = "base"
    # back off hard once this fraction of KV is resident (pre-eviction guard)
    kv_backoff_util = 0.92

    def __init__(self, predictor: Optional[BatchLatencyPredictor] = None,
                 max_budget: int = 4096, budget_quantum: int = 1,
                 max_iter_time: float = 0.05, class_shares=None):
        self.predictor = predictor or BatchLatencyPredictor()
        # class_shares: rank -> weight for the within-round chunk-budget
        # split (see forwarder.DEFAULT_CLASS_SHARES); None = class-blind.
        self.F = BatchForwarder(self.predictor, max_budget, budget_quantum,
                                class_shares=class_shares)
        self.max_budget = max_budget
        # Responsiveness guard: cap a single iteration's target duration so a
        # large chunk scheduled during a lull cannot blind the server to
        # arrivals (static-chunk systems get this implicitly from their chunk
        # size; dynamic chunking needs it explicitly).
        self.max_iter_time = max_iter_time
        self.rho = 1000.0          # tokens/s EMA (Eq. 9's rho_t)
        self._rho_beta = 0.9
        self.last_kv: Optional[KVPressure] = None

    def schedule(self, t: float, waiting: Sequence[Request],
                 prefilling: Sequence[Request],
                 decoding: Sequence[Request],
                 kv: Optional[KVPressure] = None) -> Optional[Decision]:
        raise NotImplementedError

    def _budget_cap(self, decoding: Sequence[Request],
                    kv: Optional[KVPressure]) -> int:
        """Effective token budget under KV pressure: every scheduled token
        becomes a cache entry, so never schedule more than fits free, and
        halve the target while evictions are happening (churn costs full
        recompute of the victim)."""
        self.last_kv = kv
        if kv is None:
            return self.max_budget
        floor = len(decoding) + 1          # liveness: decodes + 1 prefill token
        cap = max(floor, kv.free_tokens)
        if kv.evictions > 0 or kv.utilization > self.kv_backoff_util:
            cap = max(floor, cap // 2)
        return min(self.max_budget, cap)

    def observe(self, batch: Sequence[Tuple], latency: float,
                kv: Optional[KVPressure] = None) -> None:
        if kv is not None:
            self.last_kv = kv
        self.predictor.observe(batch, latency)
        if latency > 0:
            # rho_t estimates how fast *prefill* work drains (Eq. 9 divides
            # remaining prefill tokens by it), so measure prefill-token
            # throughput on rounds that carry prefill work; decode-only
            # rounds would bias the estimate far low. Entries may be (c, u)
            # or (c, u, s) — speculative verify rows (base width c - s <= 1)
            # are decode work and must not count as prefill drain.
            prefill_tokens = sum(
                e[0] for e in batch
                if e[0] - (e[2] if len(e) > 2 else 0) > 1)
            if prefill_tokens > 0:
                tput = prefill_tokens / latency
                self.rho = self._rho_beta * self.rho + (1 - self._rho_beta) * tput


class SlidingServeScheduler(SchedulerBase):
    name = "slidingserve"

    def __init__(self, predictor=None, max_budget: int = 4096,
                 alpha: float = 0.5, budget_quantum: int = 1,
                 enable_mlps: bool = True, enable_bc: bool = True,
                 enable_sliding: bool = True, clamp_current: bool = True,
                 knapsack_granularity: int = 16, max_iter_time: float = 0.05,
                 objective: str = "tokens",
                 class_shares=DEFAULT_CLASS_SHARES):
        # SlidingServe defaults to class-aware budget shares (the baselines
        # stay class-blind: that is what they are baselines *of*).
        super().__init__(predictor, max_budget, budget_quantum,
                         max_iter_time=max_iter_time,
                         class_shares=class_shares)
        self.objective = objective
        self.alpha = alpha
        self.enable_mlps = enable_mlps
        self.enable_bc = enable_bc
        self.enable_sliding = enable_sliding
        self.clamp_current = clamp_current
        self.knapsack_granularity = knapsack_granularity

    def _sorted(self, t, waiting, prefilling):
        if self.enable_mlps:
            return sort_candidates(prefilling, waiting, t, self.rho, self.alpha)
        cands = list(prefilling) + list(waiting)
        return sorted(cands, key=lambda r: r.ttft_deadline())   # EDF fallback

    def schedule(self, t, waiting, prefilling, decoding, kv=None):
        if not (waiting or prefilling or decoding):
            return None
        P = self._sorted(t, waiting, prefilling)
        D = list(decoding)
        t_cur, t_next = window_bounds(D, t, default_cur=self.max_iter_time)
        t_cur = min(t_cur, self.max_iter_time)
        # KV pressure (paged engine): cap the token budget at what the cache
        # can absorb so SlidingChunker/BatchConstructor never schedule chunks
        # whose KV writes would immediately evict an active request.
        max_budget = self._budget_cap(D, kv)

        # (4) Violation Checker on the maximal candidate batch. The paper's
        # risk test (slack < T_full) is refined with the Eq.-10 urgency gate:
        # a request is at *actionable* risk only if it also cannot complete at
        # the observed prefill pace — otherwise normal capped rounds will
        # finish it and a dedicated BC batch would pay its cost for nothing.
        route = "sliding"
        if self.enable_bc and P:
            t_full, _ = self.F.forward(D, P, max_budget)
            from repro.core.sorter import normalized_urgency
            if any(r.ttft_slack(t) < t_full and r.ttft_slack(t) > 0
                   and normalized_urgency(r, t, self.rho) > 1.0 for r in P):
                res = batch_constructor(D, P, max_budget, t, self.F,
                                        granularity=self.knapsack_granularity)
                if res is not None:
                    budget, alloc = res
                    pred = self.predictor.predict(self.F.to_batch(alloc))
                    return Decision(alloc, pred, budget, "construct")

        # (4b) Speculation risk: verify rows pay fixed multi-token cost for a
        # variable token yield, so accepted-length *variance* is TBT risk —
        # a volatile acceptance rate means some rows' TBT gains evaporate
        # while their verify cost stays in the round. Tighten the current
        # window by the time one std of at-risk draft tokens per decode row
        # costs at the observed pace, shrinking chunk budgets exactly when
        # speculation is least dependable. (Expected verify *cost* is already
        # priced by F.to_batch widening decode rows; this handles the risk.)
        if getattr(self.F, "spec_draft_tokens", 0.0) > 0 and D:
            risk_tokens = self.F.spec_len_std * len(D)
            t_cur = max(t_cur - risk_tokens / max(self.rho, 1e-6), 1e-4)

        # (5) SlidingChunker branch (or single-step when ablated off).
        if self.enable_sliding:
            budget, alloc, pred = sliding_chunker(
                D, P, max_budget, t, t_cur, t_next, self.F,
                clamp_current=self.clamp_current, objective=self.objective)
        else:
            # single-step ablation honors the same KV cap as the other paths,
            # else its ablated runs pay eviction churn the baselines don't
            budget = min(self.F.time_to_budget(D, P, t_cur), max_budget)
            pred, alloc = self.F.forward(D, P, budget)
        if not alloc and (D or P):
            # liveness guard: never idle while work is pending
            budget = max(self.F.time_to_budget(D, P, t_cur), len(D) + 1)
            pred, alloc = self.F.forward(D, P, budget)
        if not alloc:
            return None
        return Decision(alloc, pred, budget, route)
