"""Batch Latency Predictor (paper §3.2).

Per-scene linear experts + a global fallback model (Eq. 5):

    T_hat = b^(m) + sum_j w_j^(m) x_j

Training combines offline initialization with online incremental updates:
sufficient statistics (X^T X, X^T y) are accumulated per scene with
exponential decay; every ``refit_interval`` observations the ridge solution is
recomputed and *hot-swapped* (the live coefficient set is replaced atomically,
mirroring the paper's background-thread calibration). A scene expert is only
activated once its sample count reaches ``expert_threshold``; otherwise the
global model answers (paper §3.2 "Model training").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.features import (NUM_FEATURES, SCENES, batch_features,
                                 features_many, scene_of)


@dataclasses.dataclass
class _LinModel:
    w: np.ndarray            # [NUM_FEATURES]
    b: float

    def predict(self, x: np.ndarray) -> float:
        return float(x @ self.w + self.b)


class _SceneStats:
    """Decayed sufficient statistics for ridge regression with intercept."""

    def __init__(self, dim: int, decay: float = 0.999):
        d = dim + 1
        self.xtx = np.zeros((d, d))
        self.xty = np.zeros(d)
        self.count = 0
        self.decay = decay
        self._xa = np.ones(d)               # reused augmented-feature buffer

    def add(self, x: np.ndarray, y: float) -> None:
        xa = self._xa
        xa[:-1] = x
        # in-place decay + rank-1 update: no per-observation allocations in
        # the serve loop's observe() path
        self.xtx *= self.decay
        self.xtx += xa[:, None] * xa[None, :]
        self.xty *= self.decay
        self.xty += xa * y
        self.count += 1

    def add_many(self, X: np.ndarray, y: np.ndarray) -> None:
        """Batched accumulation, equivalent to ``add`` in sample order:
        one decayed outer-product GEMM instead of n rank-1 updates."""
        n = len(y)
        if n == 0:
            return
        Xa = np.concatenate([X, np.ones((n, 1))], axis=1)
        w = self.decay ** np.arange(n - 1, -1, -1.0)
        self.xtx = (self.decay ** n) * self.xtx + (Xa * w[:, None]).T @ Xa
        self.xty = (self.decay ** n) * self.xty + (Xa * w[:, None]).T @ y
        self.count += n

    def solve(self, ridge: float) -> Optional[_LinModel]:
        if self.count == 0:
            return None
        d = self.xtx.shape[0]
        reg = ridge * np.eye(d)
        reg[-1, -1] = 1e-12  # do not regularize the intercept
        try:
            beta = np.linalg.solve(self.xtx + reg, self.xty)
        except np.linalg.LinAlgError:
            return None
        return _LinModel(w=beta[:-1], b=float(beta[-1]))


class BatchLatencyPredictor:
    """Scene-expert linear latency predictor with online hot-swap refit."""

    def __init__(self, ridge: float = 1e-4, expert_threshold: int = 64,
                 refit_interval: int = 256, feature_scale: float = 1e-4,
                 decay: float = 0.9995):
        self.ridge = ridge
        self.expert_threshold = expert_threshold
        self.refit_interval = refit_interval
        # feature magnitudes span ~6 orders; scale for conditioning
        self.fscale = feature_scale
        self.stats: Dict[str, _SceneStats] = {
            s: _SceneStats(NUM_FEATURES, decay) for s in SCENES}
        self.global_stats = _SceneStats(NUM_FEATURES, decay)
        self.models: Dict[str, Optional[_LinModel]] = {s: None for s in SCENES}
        self.global_model: Optional[_LinModel] = None
        self._since_refit = 0
        self.observed = 0

    # ---- featurization helpers ----------------------------------------------
    def _x(self, feats: np.ndarray) -> np.ndarray:
        return feats * self.fscale

    # ---- offline init (paper: "offline-collected batch runtime data") -------
    def fit_offline(self, samples: Sequence[Tuple[Sequence[Tuple[int, int]], float]]):
        if not samples:
            self._refit()
            return
        # batched accumulation: featurize once, then one decayed GEMM per
        # scene (and one global) instead of per-sample rank-1 updates.
        # Grouping by scene preserves each accumulator's sample order, so
        # the sufficient statistics match the sequential path.
        X, scenes, _ = features_many([b for b, _ in samples])
        X = X * self.fscale
        ys = np.asarray([y for _, y in samples], np.float64)
        for s in SCENES:
            idx = np.flatnonzero(scenes == s)
            if len(idx):
                self.stats[s].add_many(X[idx], ys[idx])
        self.global_stats.add_many(X, ys)
        self.observed += len(samples)
        self._refit()

    # ---- online path ---------------------------------------------------------
    def observe(self, batch, latency: float) -> None:
        self._accumulate(batch, latency)
        self._since_refit += 1
        if self._since_refit >= self.refit_interval:
            self._refit()   # hot swap

    def _accumulate(self, batch, y: float) -> None:
        feats, scene = batch_features(batch), scene_of(batch)
        x = self._x(feats)
        self.stats[scene].add(x, y)
        self.global_stats.add(x, y)
        self.observed += 1

    def _refit(self) -> None:
        new_models = {}
        for s in SCENES:
            st = self.stats[s]
            new_models[s] = st.solve(self.ridge) if st.count >= self.expert_threshold else None
        new_global = self.global_stats.solve(self.ridge)
        # hot swap: replace the whole coefficient set atomically
        self.models = new_models
        self.global_model = new_global
        self._since_refit = 0

    # ---- inference ------------------------------------------------------------
    def predict(self, batch) -> float:
        if not batch:
            return 0.0
        feats, scene = batch_features(batch), scene_of(batch)
        x = self._x(feats)
        model = self.models.get(scene) or self.global_model
        if model is None:
            # cold start: crude proportional guess keeps the scheduler alive
            return 1e-5 * float(sum(e[0] for e in batch) + 1)
        return max(model.predict(x), 1e-6)

    # ---- evaluation (paper Table 5) -------------------------------------------
    def predict_many(self, batches) -> np.ndarray:
        """Vectorized ``predict``: one matrix-vector product per scene expert
        instead of a Python-level dot per sample (keeps bulk evaluation off
        the serve loop's critical path)."""
        n = len(batches)
        yh = np.zeros(n)
        if n == 0:
            return yh
        X, scenes, csum = features_many(batches)
        X = X * self.fscale
        empty = np.asarray([not b for b in batches])
        for s in SCENES:
            idx = np.flatnonzero(scenes == s)
            if not len(idx):
                continue
            model = self.models.get(s) or self.global_model
            if model is None:
                # cold start: crude proportional guess (see ``predict``)
                yh[idx] = 1e-5 * (csum[idx] + 1.0)
            else:
                yh[idx] = np.maximum(X[idx] @ model.w + model.b, 1e-6)
        yh[empty] = 0.0
        return yh

    def evaluate(self, samples) -> dict:
        ys = np.asarray([y for _, y in samples], np.float64)
        yh = self.predict_many([b for b, _ in samples])
        err = yh - ys
        ss_res = float(np.sum(err ** 2))
        ss_tot = float(np.sum((ys - ys.mean()) ** 2)) or 1e-12
        return {
            "mae": float(np.mean(np.abs(err))),
            "rmse": float(np.sqrt(np.mean(err ** 2))),
            "r2": 1.0 - ss_res / ss_tot,
            "n": len(ys),
        }
