"""Batch Latency Predictor (paper §3.2).

Per-scene linear experts + a global fallback model (Eq. 5):

    T_hat = b^(m) + sum_j w_j^(m) x_j

Training combines offline initialization with online incremental updates:
sufficient statistics (X^T X, X^T y) are accumulated per scene with
exponential decay; every ``refit_interval`` observations the ridge solution is
recomputed and *hot-swapped* (the live coefficient set is replaced atomically,
mirroring the paper's background-thread calibration). A scene expert is only
activated once its sample count reaches ``expert_threshold``; otherwise the
global model answers (paper §3.2 "Model training").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.features import NUM_FEATURES, SCENES, batch_features, scene_of


@dataclasses.dataclass
class _LinModel:
    w: np.ndarray            # [NUM_FEATURES]
    b: float

    def predict(self, x: np.ndarray) -> float:
        return float(x @ self.w + self.b)


class _SceneStats:
    """Decayed sufficient statistics for ridge regression with intercept."""

    def __init__(self, dim: int, decay: float = 0.999):
        d = dim + 1
        self.xtx = np.zeros((d, d))
        self.xty = np.zeros(d)
        self.count = 0
        self.decay = decay

    def add(self, x: np.ndarray, y: float) -> None:
        xa = np.concatenate([x, [1.0]])
        self.xtx = self.decay * self.xtx + np.outer(xa, xa)
        self.xty = self.decay * self.xty + xa * y
        self.count += 1

    def solve(self, ridge: float) -> Optional[_LinModel]:
        if self.count == 0:
            return None
        d = self.xtx.shape[0]
        reg = ridge * np.eye(d)
        reg[-1, -1] = 1e-12  # do not regularize the intercept
        try:
            beta = np.linalg.solve(self.xtx + reg, self.xty)
        except np.linalg.LinAlgError:
            return None
        return _LinModel(w=beta[:-1], b=float(beta[-1]))


class BatchLatencyPredictor:
    """Scene-expert linear latency predictor with online hot-swap refit."""

    def __init__(self, ridge: float = 1e-4, expert_threshold: int = 64,
                 refit_interval: int = 256, feature_scale: float = 1e-4,
                 decay: float = 0.9995):
        self.ridge = ridge
        self.expert_threshold = expert_threshold
        self.refit_interval = refit_interval
        # feature magnitudes span ~6 orders; scale for conditioning
        self.fscale = feature_scale
        self.stats: Dict[str, _SceneStats] = {
            s: _SceneStats(NUM_FEATURES, decay) for s in SCENES}
        self.global_stats = _SceneStats(NUM_FEATURES, decay)
        self.models: Dict[str, Optional[_LinModel]] = {s: None for s in SCENES}
        self.global_model: Optional[_LinModel] = None
        self._since_refit = 0
        self.observed = 0

    # ---- featurization helpers ----------------------------------------------
    def _x(self, feats: np.ndarray) -> np.ndarray:
        return feats * self.fscale

    # ---- offline init (paper: "offline-collected batch runtime data") -------
    def fit_offline(self, samples: Sequence[Tuple[Sequence[Tuple[int, int]], float]]):
        for batch, y in samples:
            self._accumulate(batch, y)
        self._refit()

    # ---- online path ---------------------------------------------------------
    def observe(self, batch, latency: float) -> None:
        self._accumulate(batch, latency)
        self._since_refit += 1
        if self._since_refit >= self.refit_interval:
            self._refit()   # hot swap

    def _accumulate(self, batch, y: float) -> None:
        feats, scene = batch_features(batch), scene_of(batch)
        x = self._x(feats)
        self.stats[scene].add(x, y)
        self.global_stats.add(x, y)
        self.observed += 1

    def _refit(self) -> None:
        new_models = {}
        for s in SCENES:
            st = self.stats[s]
            new_models[s] = st.solve(self.ridge) if st.count >= self.expert_threshold else None
        new_global = self.global_stats.solve(self.ridge)
        # hot swap: replace the whole coefficient set atomically
        self.models = new_models
        self.global_model = new_global
        self._since_refit = 0

    # ---- inference ------------------------------------------------------------
    def predict(self, batch) -> float:
        if not batch:
            return 0.0
        feats, scene = batch_features(batch), scene_of(batch)
        x = self._x(feats)
        model = self.models.get(scene) or self.global_model
        if model is None:
            # cold start: crude proportional guess keeps the scheduler alive
            return 1e-5 * float(sum(c for c, _ in batch) + 1)
        return max(model.predict(x), 1e-6)

    # ---- evaluation (paper Table 5) -------------------------------------------
    def evaluate(self, samples) -> dict:
        ys, yh = [], []
        for batch, y in samples:
            ys.append(y)
            yh.append(self.predict(batch))
        ys, yh = np.asarray(ys), np.asarray(yh)
        err = yh - ys
        ss_res = float(np.sum(err ** 2))
        ss_tot = float(np.sum((ys - ys.mean()) ** 2)) or 1e-12
        return {
            "mae": float(np.mean(np.abs(err))),
            "rmse": float(np.sqrt(np.mean(err ** 2))),
            "r2": 1.0 - ss_res / ss_tot,
            "n": len(ys),
        }
