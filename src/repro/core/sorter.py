"""Multi-Level Priority Sorter (paper §3.3, Eq. 6-13).

Lexicographic key K_i(t) = (1 - g_i, 1 - e_i(t), r_i(t)):

1. safeguard priority (g_i) — protected requests first;
2. urgency priority — e_i = 1[u_i(t) > alpha] with normalized urgency
   u_i = r_i / (rho_t * max(s_i, eps)) (Eq. 10): remaining work relative to
   remaining slack, measured in recent system throughput rho_t;
3. short-remaining priority — fewer remaining prefill tokens first.

One addition taken from the paper's §5.2 discussion ("lowering the scheduling
priority of requests that have already violated their SLOs"): an outermost
*relegation* level pushes already-expired requests behind everything else, so
capacity is reserved for requests that can still meet their deadline.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.serving.request import Request

EPS = 1e-6


def normalized_urgency(req: Request, t: float, rho: float, eps: float = EPS) -> float:
    """u_i(t) of Eq. 10."""
    r = req.remaining_prefill()
    s = req.ttft_slack(t)
    return r / (max(rho, 1.0) * max(s, eps))


def priority_key(req: Request, t: float, rho: float, alpha: float,
                 relegate_expired: bool = True) -> Tuple:
    g = 1 if req.guard else 0
    u = normalized_urgency(req, t, rho)
    e = 1 if u > alpha else 0
    expired = 1 if (relegate_expired and req.ttft_slack(t) < 0) else 0
    # cache-aware tie-break (after remaining work, before FIFO): among equal
    # remaining-prefill candidates, prefer the larger frozen-prefix hit —
    # its KV is already resident, so finishing it frees budget soonest and
    # keeps the shared chain hot.
    return (expired, 1 - g, 1 - e, req.remaining_prefill(),
            -req.cached_prefix, req.arrival)


def sort_candidates(prefilling: Sequence[Request], waiting: Sequence[Request],
                    t: float, rho: float, alpha: float = 1.0,
                    relegate_expired: bool = True) -> List[Request]:
    """Eq. 6 + Eq. 13: merge and LexSort ascending."""
    cands = list(prefilling) + list(waiting)
    return sorted(cands, key=lambda r: priority_key(r, t, rho, alpha, relegate_expired))
