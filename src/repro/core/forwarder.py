"""BatchForwarder F (paper Alg. 1/2): Forward / Pred / TimeToBudget.

``Forward(D, P, b)`` materializes the batch a budget of ``b`` tokens buys
under vLLM's allocation rule — every decode request gets 1 token, then
prefill/waiting requests take ``min(remaining, budget_left)`` in priority
order — and predicts its execution time. ``TimeToBudget`` inverts the
predictor by binary search (the paper's stated implementation).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.serving.request import Request

Alloc = List[Tuple[Request, int]]


class BatchForwarder:
    def __init__(self, predictor, max_budget: int, budget_quantum: int = 1):
        self.predictor = predictor
        self.max_budget = max_budget
        self.quantum = budget_quantum  # beyond-paper: bucket budgets for JIT warmth

    # ---- batch materialization ------------------------------------------------
    def allocate(self, decoding: Sequence[Request], prefill_sorted: Sequence[Request],
                 budget: int) -> Alloc:
        alloc: Alloc = [(r, 1) for r in decoding]
        left = budget - len(decoding)
        for r in prefill_sorted:
            if left <= 0:
                break
            take = min(r.remaining_prefill(), left)
            if take > 0:
                alloc.append((r, take))
                left -= take
        return alloc

    @staticmethod
    def to_batch(alloc: Alloc) -> List[Tuple[int, int]]:
        """(c_i, u_i) pairs for the predictor/features."""
        return [(n, r.context_len()) for r, n in alloc]

    # ---- F.Forward / F.Pred / F.TimeToBudget -----------------------------------
    def forward(self, decoding, prefill_sorted, budget: int) -> Tuple[float, Alloc]:
        budget = self._q(budget)
        alloc = self.allocate(decoding, prefill_sorted, budget)
        return self.predictor.predict(self.to_batch(alloc)), alloc

    def pred(self, budget: int, decoding, prefill_sorted) -> float:
        budget = self._q(budget)
        alloc = self.allocate(decoding, prefill_sorted, budget)
        return self.predictor.predict(self.to_batch(alloc))

    def forward_next(self, decoding, prefill_sorted, alloc1: Alloc,
                     budget2: int):
        """(predicted_time, scheduled_tokens) of the next iteration's batch,
        with the queue advanced past window 1 (see pred_next)."""
        batch = self._next_batch(decoding, prefill_sorted, alloc1, budget2)
        return self.predictor.predict(batch), sum(c for c, _ in batch)

    def time_to_budget_next(self, decoding, prefill_sorted, alloc1: Alloc,
                            t_limit: float) -> int:
        """TimeToBudget evaluated on the post-window-1 queue."""
        lo = len(decoding)
        hi = self.max_budget
        pred = lambda b: self.predictor.predict(
            self._next_batch(decoding, prefill_sorted, alloc1, b))
        if pred(hi) <= t_limit:
            return hi
        if pred(lo) > t_limit:
            return lo
        while hi - lo > max(1, self.quantum):
            mid = (lo + hi) // 2
            if pred(mid) <= t_limit:
                lo = mid
            else:
                hi = mid
        return lo

    def _next_batch(self, decoding, prefill_sorted, alloc1: Alloc, budget2: int):
        taken = {id(r): n for r, n in alloc1}
        batch = [(1, r.context_len() + 1) for r in decoding]
        left = budget2 - len(batch)
        for r in prefill_sorted:
            got = taken.get(id(r), 0)
            rem = r.remaining_prefill() - got
            if rem <= 0:
                if left > 0:
                    batch.append((1, r.prompt_len))
                    left -= 1
                continue
            if left <= 0:
                continue
            take = min(rem, left)
            batch.append((take, r.context_len() + got))
            left -= take
        return batch

    def pred_next(self, decoding, prefill_sorted, alloc1: Alloc, budget2: int) -> float:
        """Predicted time of the *next* iteration's batch, with the queue
        advanced past window 1: chunks allocated in window 1 are subtracted
        and prefills that complete become decodes. (Alg. 1 writes
        Pred(B_sigma - b, D, P) on the unchanged queue; taken literally both
        windows would allocate the same work twice and deferral would always
        look free.)"""
        return self.predictor.predict(
            self._next_batch(decoding, prefill_sorted, alloc1, budget2))

    def time_to_budget(self, decoding, prefill_sorted, t_limit: float) -> int:
        """Largest budget whose predicted time fits in ``t_limit``."""
        lo = len(decoding)
        hi = self.max_budget
        if self.pred(hi, decoding, prefill_sorted) <= t_limit:
            return hi
        if self.pred(lo, decoding, prefill_sorted) > t_limit:
            return lo
        while hi - lo > max(1, self.quantum):
            mid = (lo + hi) // 2
            if self.pred(mid, decoding, prefill_sorted) <= t_limit:
                lo = mid
            else:
                hi = mid
        return lo

    def _q(self, budget: int) -> int:
        if self.quantum <= 1:
            return budget
        return max(0, budget // self.quantum * self.quantum)
