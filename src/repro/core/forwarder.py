"""BatchForwarder F (paper Alg. 1/2): Forward / Pred / TimeToBudget.

``Forward(D, P, b)`` materializes the batch a budget of ``b`` tokens buys
under vLLM's allocation rule — every decode request gets 1 token, then
prefill/waiting requests take ``min(remaining, budget_left)`` in priority
order — and predicts its execution time. ``TimeToBudget`` inverts the
predictor by binary search (the paper's stated implementation).

``class_shares`` makes the within-round prefill split **SLO-class-aware**:
instead of handing the whole chunk budget to the priority order class-blind,
each class rank present gets a weighted share (interactive > standard >
batch by default), consumed in priority order within the class; whatever a
class cannot use spills over to the global priority order (work-conserving,
so the round never runs under-budget because one class ran dry). A
single-class round reduces exactly to the legacy split.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.request import ReqState, Request

Alloc = List[Tuple[Request, int]]

# Weighted chunk-budget shares by SLO class rank (see request.SLO_CLASS_RANK:
# 0 = interactive, 1 = standard/dialogue, 2 = batch/summarization).
DEFAULT_CLASS_SHARES: Dict[int, int] = {0: 4, 1: 2, 2: 1}


class BatchForwarder:
    def __init__(self, predictor, max_budget: int, budget_quantum: int = 1,
                 class_shares: Optional[Dict[int, int]] = None):
        self.predictor = predictor
        self.max_budget = max_budget
        self.quantum = budget_quantum  # beyond-paper: bucket budgets for JIT warmth
        self.class_shares = class_shares   # None = class-blind legacy split
        # Speculation price signals, written by the serving engine each
        # speculative round and read here + by the scheduler: expected draft
        # tokens riding each decode row (so to_batch prices decode rows as
        # (1+s)-wide verify rows), and the std of the accepted length (the
        # chunker's TBT-risk input — verify cost is paid up front while its
        # token yield varies). Both 0.0 means plain decode pricing.
        self.spec_draft_tokens = 0.0
        self.spec_len_std = 0.0

    # ---- batch materialization ------------------------------------------------
    def allocate(self, decoding: Sequence[Request], prefill_sorted: Sequence[Request],
                 budget: int) -> Alloc:
        alloc: Alloc = [(r, 1) for r in decoding]
        left = budget - len(decoding)
        if left <= 0:
            return alloc
        if self.class_shares is not None:
            ranks = {r.class_rank() for r in prefill_sorted}
            if len(ranks) > 1:
                return alloc + self._allocate_shares(prefill_sorted, left,
                                                     ranks)
        for r in prefill_sorted:
            if left <= 0:
                break
            take = min(r.remaining_prefill(), left)
            if take > 0:
                alloc.append((r, take))
                left -= take
        return alloc

    def _allocate_shares(self, prefill_sorted: Sequence[Request], left: int,
                         ranks) -> Alloc:
        """Weighted per-class shares, then work-conserving spillover.

        Pass 1 caps each class at ``left * w_c / sum(w)`` (consumed in the
        caller's priority order within the class); pass 2 hands every token
        pass 1 could not place back to the plain priority order, topping up
        earlier grants first. Exactly ``min(left, pending)`` tokens are
        placed — the split never costs throughput, only rearranges it."""
        w = {k: self.class_shares.get(k, 1) for k in ranks}
        total_w = sum(w.values())
        share = {k: (left * w[k]) // total_w for k in ranks}
        taken: Dict[int, int] = {}
        for r in prefill_sorted:
            k = r.class_rank()
            give = min(r.remaining_prefill(), share[k])
            if give > 0:
                taken[id(r)] = give
                share[k] -= give
        spill = left - sum(taken.values())
        for r in prefill_sorted:
            if spill <= 0:
                break
            give = min(r.remaining_prefill() - taken.get(id(r), 0), spill)
            if give > 0:
                taken[id(r)] = taken.get(id(r), 0) + give
                spill -= give
        return [(r, taken[id(r)]) for r in prefill_sorted if id(r) in taken]

    def _spec_s(self) -> int:
        """Expected drafts per decode row, rounded to the batch-entry grain."""
        return int(round(self.spec_draft_tokens))

    def to_batch(self, alloc: Alloc) -> List[Tuple]:
        """(c_i, u_i[, s_i]) entries for the predictor/features; decode rows
        widen to expected verify width when the engine is speculating."""
        s = self._spec_s()
        out: List[Tuple] = []
        for r, n in alloc:
            if n <= 1 and s > 0 and r.state == ReqState.DECODING:
                out.append((1 + s, r.context_len(), s))
            else:
                out.append((n, r.context_len()))
        return out

    # ---- F.Forward / F.Pred / F.TimeToBudget -----------------------------------
    def forward(self, decoding, prefill_sorted, budget: int) -> Tuple[float, Alloc]:
        budget = self._q(budget)
        alloc = self.allocate(decoding, prefill_sorted, budget)
        return self.predictor.predict(self.to_batch(alloc)), alloc

    def pred(self, budget: int, decoding, prefill_sorted) -> float:
        budget = self._q(budget)
        alloc = self.allocate(decoding, prefill_sorted, budget)
        return self.predictor.predict(self.to_batch(alloc))

    def forward_next(self, decoding, prefill_sorted, alloc1: Alloc,
                     budget2: int):
        """(predicted_time, scheduled_tokens) of the next iteration's batch,
        with the queue advanced past window 1 (see pred_next)."""
        batch = self._next_batch(decoding, prefill_sorted, alloc1, budget2)
        return self.predictor.predict(batch), sum(e[0] for e in batch)

    def time_to_budget_next(self, decoding, prefill_sorted, alloc1: Alloc,
                            t_limit: float) -> int:
        """TimeToBudget evaluated on the post-window-1 queue."""
        lo = len(decoding)
        hi = self.max_budget
        pred = lambda b: self.predictor.predict(
            self._next_batch(decoding, prefill_sorted, alloc1, b))
        if pred(hi) <= t_limit:
            return hi
        if pred(lo) > t_limit:
            return lo
        while hi - lo > max(1, self.quantum):
            mid = (lo + hi) // 2
            if pred(mid) <= t_limit:
                lo = mid
            else:
                hi = mid
        return lo

    def _next_batch(self, decoding, prefill_sorted, alloc1: Alloc, budget2: int):
        taken = {id(r): n for r, n in alloc1}
        s = self._spec_s()
        if s > 0:
            batch = [(1 + s, r.context_len() + 1, s) for r in decoding]
        else:
            batch = [(1, r.context_len() + 1) for r in decoding]
        left = budget2 - len(batch)
        for r in prefill_sorted:
            got = taken.get(id(r), 0)
            rem = r.remaining_prefill() - got
            if rem <= 0:
                if left > 0:
                    batch.append((1, r.prompt_len))
                    left -= 1
                continue
            if left <= 0:
                continue
            take = min(rem, left)
            batch.append((take, r.context_len() + got))
            left -= take
        return batch

    def pred_next(self, decoding, prefill_sorted, alloc1: Alloc, budget2: int) -> float:
        """Predicted time of the *next* iteration's batch, with the queue
        advanced past window 1: chunks allocated in window 1 are subtracted
        and prefills that complete become decodes. (Alg. 1 writes
        Pred(B_sigma - b, D, P) on the unchanged queue; taken literally both
        windows would allocate the same work twice and deferral would always
        look free.)"""
        return self.predictor.predict(
            self._next_batch(decoding, prefill_sorted, alloc1, budget2))

    def time_to_budget(self, decoding, prefill_sorted, t_limit: float) -> int:
        """Largest budget whose predicted time fits in ``t_limit``."""
        lo = len(decoding)
        hi = self.max_budget
        if self.pred(hi, decoding, prefill_sorted) <= t_limit:
            return hi
        if self.pred(lo, decoding, prefill_sorted) > t_limit:
            return lo
        while hi - lo > max(1, self.quantum):
            mid = (lo + hi) // 2
            if self.pred(mid, decoding, prefill_sorted) <= t_limit:
                lo = mid
            else:
                hi = mid
        return lo

    def _q(self, budget: int) -> int:
        if self.quantum <= 1:
            return budget
        return max(0, budget // self.quantum * self.quantum)
