"""SlidingServe core: the paper's contribution.

- ``features`` / ``predictor`` — §3.2 batch latency predictor (per-scene
  linear experts over the 7-dim feature vector of Table 1, offline init +
  online incremental refit with hot-swap).
- ``sorter`` — §3.3 Multi-Level Priority Sorter (Eq. 6-13).
- ``forwarder`` — the BatchForwarder F of Alg. 1/2 (Forward / Pred /
  TimeToBudget).
- ``sliding_chunker`` — §3.4 Alg. 1 (two-iteration sliding-window budget
  split via discrete ternary search).
- ``batch_constructor`` — §3.5 Alg. 2 (anchor + 0/1-knapsack request
  selection under TTFT risk).
- ``scheduler`` — the closed loop (Fig. 3) + the Violation Checker routing.
- ``baselines`` — Sarathi-EDF, QoServe-like, vLLM-FCFS, single-step greedy.
"""
from repro.core.scheduler import KVPressure, SlidingServeScheduler  # noqa: F401
from repro.core.baselines import (  # noqa: F401
    FCFSStaticScheduler, QoServeLikeScheduler, SarathiEDFScheduler,
    SingleStepGreedyScheduler,
)
