"""SlidingChunker (paper §3.4, Algorithm 1).

Instead of greedily taking the largest budget the current iteration's decode
slack allows, jointly optimize the budget across a sliding window of two
consecutive iterations. Window bounds follow Eq. 14/15 over the safeguarded
decode set:

    T_cur  = min_i s_i(t)
    T_next = min_i (s_i(t) - T_cur + L_tbt_i)

Two selectable objectives, both driven by Alg. 1's skeleton (TimeToBudget
inversion, discrete ternary search, candidate set {l0, r0, m}, prefer-larger
tie-break):

* ``objective="tokens"`` (default) — maximize tokens processed across BOTH
  windows subject to both deadlines, window 2 evaluated on the post-window-1
  queue with its *actual* remaining time T_next(b) = min_i(s_i + L_tbt) -
  T_hat(b). This is Figure 1's semantics ("processes 100 more tokens ...
  before the next iteration's deadline"): an over-greedy window 1 eats window
  2's slack; an over-timid one wastes window 1. Ties (within ``tie_tol``)
  break toward lower total time, then larger b.
* ``objective="paper"`` — the literal Alg. 1 objective
  min_b T_hat(b) + T_hat(B_sigma - b). Note that under light load (pending
  work < B_sigma) this is degenerate: both windows draw from the same queue,
  so deferring work is always predicted (spuriously) to be free; it is kept
  for the fidelity ablation and behaves like the paper's setting under
  saturation.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

from repro.core.forwarder import Alloc, BatchForwarder
from repro.serving.request import Request


def window_bounds(decoding: Sequence[Request], t: float,
                  default_cur: float = 1.0) -> Tuple[float, float]:
    """Eq. 14 / Eq. 15 over the safeguarded decode set."""
    safe = [r for r in decoding if r.is_decoding()]
    if not safe:
        return default_cur, default_cur
    t_cur = min(r.sched_decode_slack(t) for r in safe)
    t_cur = max(t_cur, 1e-4)
    t_next = min(r.sched_decode_slack(t) - t_cur + r.tbt_slo for r in safe)
    t_next = max(t_next, 1e-4)
    return t_cur, t_next


def sliding_chunker(
    decoding: Sequence[Request],
    prefill_sorted: Sequence[Request],
    max_budget: int,
    t: float,
    t_cur: float,
    t_next: float,
    F: BatchForwarder,
    *,
    ternary_stop: int = 30,
    clamp_current: bool = True,
    objective: str = "tokens",
    deviate_margin: float = 0.08,
) -> Tuple[int, Alloc, float]:
    """Algorithm 1. Returns (B_star, A_star, predicted_time_cur)."""
    b_cur = F.time_to_budget(decoding, prefill_sorted, t_cur)
    b_next = F.time_to_budget(decoding, prefill_sorted, t_next)
    b_sum = b_cur + b_next

    # window-2 deadline base: T_next(b) = slack_min_with_tbt - T_hat(b)
    safe = [r for r in decoding if r.is_decoding()]
    next_deadline_base = (min(r.sched_decode_slack(t) + r.tbt_slo for r in safe)
                          if safe else t_cur + t_next)

    total_work = len(decoding) + sum(r.remaining_prefill() for r in prefill_sorted)
    l = len(decoding)
    r = min(max_budget, b_cur) if clamp_current else max_budget
    r = min(r, total_work)   # budget beyond pending work buys nothing
    r = max(r, l)
    l0, r0 = l, r

    def evaluate(b: int):
        """Returns (neg_tokens, total_time, t_b, alloc) for ranking."""
        t_b, alloc = F.forward(decoding, prefill_sorted, b)
        if objective == "paper":
            t_n = F.pred(max(b_sum - b, len(decoding)), decoding, prefill_sorted)
            return (0.0, t_b + t_n, t_b, alloc)
        t2_limit = max(next_deadline_base - t_b, 1e-4)
        b2 = F.time_to_budget_next(decoding, prefill_sorted, alloc, t2_limit)
        t_n, tokens2 = F.forward_next(decoding, prefill_sorted, alloc, b2)
        tokens1 = sum(n for _, n in alloc)
        return (-(tokens1 + tokens2), t_b + t_n, t_b, alloc)

    lo, hi = l, r
    while hi - lo > ternary_stop:
        m1 = lo + (hi - lo) // 3
        m2 = hi - (hi - lo) // 3
        if evaluate(m1)[:2] <= evaluate(m2)[:2]:
            hi = m2 - 1
        else:
            lo = m1 + 1
    m = (lo + hi) // 2

    # The maximal clamped budget r0 is the incumbent (Alg. 1's prefer-larger
    # tie-break, generalized to float predictions): a smaller budget is chosen
    # only when the two-window evaluation shows a *strict* token win by
    # ``deviate_margin`` — window 1 is the only window that actually executes,
    # so marginal/artifactual "wins" for deferral (which would starve prefill
    # or even deadlock the server) never outrank greedy. On a flat latency
    # landscape the chunker thus degrades gracefully to clamped greedy; it
    # activates exactly when the predictor sees real convexity (long-chunk
    # self-attention, overhead-dominated regimes).
    best_b = r0
    best = evaluate(r0)
    for b in sorted({l0, m} - {r0}, reverse=True):
        sc = evaluate(b)
        if sc[0] < best[0] - deviate_margin * max(abs(best[0]), 1.0):
            best, best_b = sc, b
    return best_b, best[3], best[2]


def single_step_budget(decoding, prefill_sorted, t_cur: float,
                       F: BatchForwarder) -> int:
    """The greedy strawman (paper §2.2): maximal budget under current slack."""
    return F.time_to_budget(decoding, prefill_sorted, t_cur)
