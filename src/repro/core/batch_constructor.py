"""BatchConstructor (paper §3.5, Algorithm 2).

When the maximal candidate batch would TTFT-violate some prefill requests,
batch construction becomes capacity-constrained request selection: each risky
request is tried as an *anchor* whose TTFT slack caps the batch execution time
(T_a = s_a -> capacity C_a via TimeToBudget); the anchor is forced in and the
remaining capacity is filled by a 0/1 knapsack over requests with slack >= s_a
(weights = remaining prefill tokens r_j, values = Eq. 18). The winning anchor
solution is picked by the lexicographic COMPARER (Eq. 21): most requests
completing prefill, then total value, then utilized budget. Selected prefill
requests receive their full remaining tokens (Eq. 22) so they emit their first
token this round.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.forwarder import Alloc, BatchForwarder
from repro.serving.request import Request


def knapsack_01(items: Sequence[Tuple[int, float]], capacity: int,
                granularity: int = 16) -> List[int]:
    """0/1 knapsack -> indices of chosen items.

    items: (weight, value). Weights/capacity are quantized to ``granularity``
    tokens (weights rounded *up*, so the solution never overfills).
    """
    if capacity <= 0 or not items:
        return []
    g = max(1, granularity)
    cap_q = capacity // g
    if cap_q <= 0:
        return []
    n = len(items)
    w_q = [max(1, -(-w // g)) for w, _ in items]  # ceil division
    vals = [v for _, v in items]
    NEG = -math.inf
    dp = [0.0] + [NEG] * cap_q
    keep = [[False] * (cap_q + 1) for _ in range(n)]
    for i in range(n):
        wi, vi = w_q[i], vals[i]
        for c in range(cap_q, wi - 1, -1):
            cand = dp[c - wi] + vi
            if dp[c - wi] > NEG and cand > dp[c]:
                dp[c] = cand
                keep[i][c] = True
    # best reachable capacity
    best_c = max(range(cap_q + 1), key=lambda c: (dp[c] if dp[c] > NEG else NEG))
    if dp[best_c] <= 0.0 and best_c == 0:
        pass
    chosen = []
    c = best_c
    for i in range(n - 1, -1, -1):
        if keep[i][c]:
            chosen.append(i)
            c -= w_q[i]
    return chosen[::-1]


def value_fn(requests: Sequence[Request], slacks: Dict[int, float]) -> Dict[int, float]:
    """Eq. 18: v_j = 1 / (sum_k s_k + r_j / sum_k r_k) over the anchor set."""
    s_sum = sum(max(slacks[r.rid], 0.0) for r in requests)
    r_sum = float(sum(r.remaining_prefill() for r in requests)) or 1.0
    out = {}
    for r in requests:
        denom = s_sum + r.remaining_prefill() / r_sum
        out[r.rid] = 1.0 / max(denom, 1e-9)
    return out


def batch_constructor(
    decoding: Sequence[Request],
    prefill_sorted: Sequence[Request],
    max_budget: int,
    t: float,
    F: BatchForwarder,
    *,
    granularity: int = 16,
    decode_guard: bool = True,
) -> Optional[Tuple[int, Alloc]]:
    """Algorithm 2. Returns (B_star, A_star) or None when there is no risk.

    ``decode_guard`` (beyond-paper, see DESIGN.md): Alg. 2 bounds batch time
    only by the anchor's TTFT slack, which can be hundreds of ms — every
    active decode then misses TBT deadlines. The guard additionally caps the
    anchor time at min_i(decode slack + one TBT period), i.e. BC may eat at
    most one recoverable TBT period from the tightest decode stream.
    """
    t_full, _ = F.forward(decoding, prefill_sorted, max_budget)
    slacks = {r.rid: r.ttft_slack(t) for r in prefill_sorted}
    risky = [r for r in prefill_sorted if slacks[r.rid] < t_full]
    if not risky:
        return None
    guard_cap = math.inf
    if decode_guard and decoding:
        guard_cap = min(r.sched_decode_slack(t) + r.tbt_slo for r in decoding)

    cands = sorted(prefill_sorted, key=lambda r: (slacks[r.rid], r.remaining_prefill()))
    a_dec: Alloc = [(r, 1) for r in decoding]
    b_dec = len(decoding)

    best_key = (-1, -math.inf, -math.inf)
    best: Optional[Tuple[int, Alloc]] = None

    for anchor in risky:
        t_a = min(slacks[anchor.rid], guard_cap)
        if t_a <= 0:
            continue  # already expired: no batch can save it
        b_a = F.time_to_budget(decoding, prefill_sorted, t_a)
        c_a = min(max_budget, b_a) - b_dec
        r_a = anchor.remaining_prefill()
        if c_a <= 0 or r_a > c_a:
            continue
        s_a = [r for r in cands if slacks[r.rid] >= t_a]
        if anchor not in s_a:
            s_a.append(anchor)
        values = value_fn(s_a, slacks)
        others = [r for r in s_a if r.rid != anchor.rid]
        items = [(r.remaining_prefill(), values[r.rid]) for r in others]
        chosen_idx = knapsack_01(items, c_a - r_a, granularity)
        selected = [others[i] for i in chosen_idx] + [anchor]
        total_v = sum(values[r.rid] for r in selected)
        total_r = sum(r.remaining_prefill() for r in selected)
        key = (len(selected), total_v, total_r)      # COMPARER, Eq. 21
        if key > best_key:
            best_key = key
            alloc = a_dec + [(r, r.remaining_prefill()) for r in selected]
            best = (b_dec + total_r, alloc)
    return best
