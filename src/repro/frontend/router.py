"""Prefix-affine multi-engine router: N replicas behind one submit surface.

One engine is one KV pool; a deployment runs many. The router's job is the
placement decision a load balancer cannot make: *which replica already holds
this prompt's prefix*. It keeps a shared :class:`PrefixDirectory` (hashed
page-granular token chains, mirrored from every replica's commit/reclaim
events) and steers each request to the replica holding the longest frozen
prefix — turning the per-engine radix cache into a fleet-wide one without
moving a single KV page across engines.

Affinity alone herds every popular prefix onto one replica until it melts,
so placement is **load-aware**: each replica's load is its outstanding token
work (uncomputed prefill + remaining decode budget) priced by an EWMA of its
measured per-token step cost, and the affine choice is overridden — spilled
to the least-loaded replica — when its load, net of the prefill the directory
hit would save, exceeds ``spill_factor`` times the cheapest alternative.
Ties break **SLO-class-aware**: among near-equal candidates, an interactive
request avoids the replica with the most latency-critical work already ahead
of it.

Replicas are pluggable: :class:`LocalReplica` wraps an in-process
:class:`InferenceServer`; ``repro.frontend.client.HttpReplica`` speaks the
same protocol to a remote HTTP backend, so the identical router class fronts
either. The router owns the global rid space (replicas must never collide)
and routes cancels/stats by rid ownership.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.frontend.prefix_directory import PrefixDirectory
from repro.serving.request import Request, class_rank
from repro.serving.server import InferenceServer

POLICIES = ("prefix-affine", "round-robin")


class LocalReplica:
    """In-process replica: one :class:`InferenceServer` (one engine) plus the
    router-facing gauges — load cost, per-token cost EWMA, SLO-class queue
    depth — and the directory listener hookup."""

    # prior for the per-token step cost EWMA (seconds/token); the first
    # measured rounds wash it out quickly (alpha below)
    COST_PRIOR_S = 2e-4
    COST_ALPHA = 0.2

    def __init__(self, index: int, server: InferenceServer):
        self.index = index
        self.server = server
        self.cost_per_token = self.COST_PRIOR_S
        self._last_work = 0        # prefill+decode tokens at last step()
        self.peak_queue_depth = 0  # max admission-queue depth observed

    @classmethod
    def build(cls, index: int, cfg, scheduler=None, slo_classes=None,
              **engine_kw) -> "LocalReplica":
        return cls(index, InferenceServer.build(
            cfg, scheduler=scheduler, slo_classes=slo_classes, **engine_kw))

    # ---- directory hookup ----------------------------------------------------
    @property
    def page_size(self) -> int:
        return getattr(self.server.core, "page_size", 0)

    @property
    def paged(self) -> bool:
        return self.server.core.cache_mode == "paged"

    def attach_directory(self, directory: PrefixDirectory) -> None:
        """Mirror this replica's committed pages into the shared directory
        (the allocator fires on_commit/on_reclaim as pages freeze/drop)."""
        if self.paged:
            self.server.core.alloc.listener = directory.listener_for(
                self.index)

    # ---- submit / cancel -----------------------------------------------------
    def submit_request(self, req: Request, prompt: Sequence[int]):
        return self.server.submit_request(req, prompt)

    def cancel(self, rid: int) -> bool:
        return self.server.cancel(rid)

    # ---- pumping + cost estimation -------------------------------------------
    def has_work(self) -> bool:
        return self.server.has_work()

    def step(self) -> List:
        """One engine round; folds the measured wall/token ratio into the
        per-token cost EWMA the router prices load with."""
        t0 = time.perf_counter()
        evts = self.server.step()
        dt = time.perf_counter() - t0
        st = self.server.core.stats
        self.peak_queue_depth = max(self.peak_queue_depth,
                                    self.server.core.queue_depth)
        work = st.prefill_tokens + st.decode_tokens
        done = work - self._last_work
        self._last_work = work
        if done > 0:
            obs = dt / done
            self.cost_per_token += self.COST_ALPHA * (obs - self.cost_per_token)
        return evts

    def progress(self) -> str:
        return self.server.core.progress

    def stalled(self) -> bool:
        return self.server.core.stalled()

    def flush(self) -> None:
        self.server._route(self.server.core.flush())

    # ---- router gauges -------------------------------------------------------
    def outstanding_tokens(self) -> int:
        return self.server.core.outstanding_tokens()

    def load_cost(self) -> float:
        """Estimated seconds of token-work this replica still owes — the
        router's load signal (queue depth x predictor-estimated cost)."""
        return self.outstanding_tokens() * self.cost_per_token

    def class_ahead(self, max_rank: int) -> int:
        return self.server.core.class_queue_depth(max_rank)

    def now(self) -> float:
        return self.server.core.now()

    # ---- lifecycle / reporting -----------------------------------------------
    def close(self, drain_s: float = 30.0) -> Dict:
        return self.server.close(drain_s)

    def stats_snapshot(self) -> Dict:
        return self.server.stats_snapshot()


class EngineRouter:
    """Submit/cancel surface over N replicas with prefix-affine dispatch.

    ``policy`` is ``"prefix-affine"`` (directory match -> deepest holder,
    load-aware spillover, class-aware tie-break) or ``"round-robin"`` (the
    cache-blind baseline the bench compares against). The router owns the
    global rid space; replicas only ever see router-assigned rids.
    """

    def __init__(self, replicas: Sequence[LocalReplica],
                 policy: str = "prefix-affine",
                 spill_factor: float = 2.0,
                 directory: Optional[PrefixDirectory] = None):
        assert replicas, "router needs at least one replica"
        assert policy in POLICIES, f"policy {policy!r}; options: {POLICIES}"
        self.replicas = list(replicas)
        self.policy = policy
        self.spill_factor = float(spill_factor)
        ps = max((r.page_size for r in self.replicas), default=0)
        self.directory = directory or PrefixDirectory(max(ps, 1))
        for rep in self.replicas:
            rep.attach_directory(self.directory)
        self._next_rid = 0
        self._owner: Dict[int, int] = {}       # rid -> replica index
        self.handles: Dict[int, object] = {}
        self._rr = 0
        # placement accounting (the bench's imbalance metric reads these)
        self.routed = [0] * len(self.replicas)
        self.work_tokens = [0] * len(self.replicas)
        self.spills = 0                        # affine choice overridden
        self.affine_hits = 0                   # routed onto a directory holder

    # ---- placement -----------------------------------------------------------
    def _least_loaded(self, rank: int) -> int:
        """Cheapest replica; near-ties (within 25%) break by how much work at
        this SLO rank or more critical is already ahead, then by load, then
        by cumulative routed work (so an idle fleet still spreads — without
        it, every idle-tie lands on index 0 and serial traffic stacks one
        replica)."""
        loads = [rep.load_cost() for rep in self.replicas]
        lo = min(loads)
        cands = [i for i, l in enumerate(loads) if l <= lo * 1.25 + 1e-9]
        return min(cands, key=lambda i: (self.replicas[i].class_ahead(rank),
                                         loads[i], self.work_tokens[i], i))

    def _choose(self, prompt: np.ndarray, rank: int,
                est_tokens: int) -> Tuple[int, int]:
        """Pick a replica for ``prompt``; returns ``(index, matched_tokens)``
        where matched_tokens > 0 means the target already holds that much of
        the prefix."""
        n = len(self.replicas)
        if n == 1:
            return 0, 0
        if self.policy == "round-robin":
            i, self._rr = self._rr, (self._rr + 1) % n
            return i, 0
        # prefix-affine: deepest directory holder, unless saturated
        matched = self.directory.match(prompt, max_tokens=len(prompt) - 1)
        fallback = self._least_loaded(rank)
        if not matched:
            return fallback, 0
        best = max(matched, key=lambda i: (matched[i],
                                           -self.replicas[i].load_cost()))
        if best == fallback:
            return best, matched[best]
        rep = self.replicas[best]
        # net load if routed here: the hit saves `matched` prefill tokens
        eff = rep.load_cost() - matched[best] * rep.cost_per_token
        alt = self.replicas[fallback]
        alt_cost = alt.load_cost() + est_tokens * alt.cost_per_token
        if eff > self.spill_factor * alt_cost:
            self.spills += 1
            return fallback, 0
        return best, matched[best]

    def _place(self, req: Request, prompt: np.ndarray) -> int:
        idx, hit = self._choose(prompt, req.class_rank(),
                                req.prompt_len + req.max_output)
        self._owner[req.rid] = idx
        self.routed[idx] += 1
        self.work_tokens[idx] += req.prompt_len + req.max_output
        if hit > 0:
            self.affine_hits += 1
            self.directory.note_routed_hit(hit)
        return idx

    # ---- submission ----------------------------------------------------------
    def _alloc_rid(self) -> int:
        rid = self._next_rid
        self._next_rid += 1
        return rid

    def submit(self, prompt: Sequence[int], slo_class: str = "standard",
               max_output: int = 64, eos_id: Optional[int] = None,
               stop_ids: Tuple[int, ...] = (),
               rid: Optional[int] = None):
        """Route and submit a prompt; returns the target replica's stream
        handle (its ``tokens()`` pumps that replica)."""
        prompt = np.asarray(prompt, np.int32)
        rid = self._alloc_rid() if rid is None else rid
        self._next_rid = max(self._next_rid, rid + 1)
        # placement needs the request's class rank and size before the
        # Request object exists; resolve the class the same way submit() does
        rank = class_rank(slo_class)
        idx, hit = self._choose(prompt, rank, len(prompt) + max_output)
        self._owner[rid] = idx
        self.routed[idx] += 1
        self.work_tokens[idx] += len(prompt) + max_output
        if hit > 0:
            self.affine_hits += 1
            self.directory.note_routed_hit(hit)
        h = self.replicas[idx].server.submit(
            prompt, slo_class=slo_class, max_output=max_output,
            eos_id=eos_id, stop_ids=stop_ids, rid=rid)
        self.handles[rid] = h
        return h

    def submit_request(self, req: Request, prompt: Sequence[int]):
        """Route and submit a pre-built request (workload replay). The
        request's ``arrival`` is interpreted as *lateness-preserving*: it
        must already be on the target replica's clock or in the past —
        ``run_open_loop`` rebases it before calling here."""
        prompt = np.asarray(prompt, np.int32)
        self._next_rid = max(self._next_rid, req.rid + 1)
        idx = self._place(req, prompt)
        h = self.replicas[idx].submit_request(req, prompt)
        self.handles[req.rid] = h
        return h

    def cancel(self, rid: int) -> bool:
        idx = self._owner.get(rid)
        if idx is None:
            return False
        return self.replicas[idx].cancel(rid)

    def owner_of(self, rid: int) -> Optional[int]:
        return self._owner.get(rid)

    # ---- pumping -------------------------------------------------------------
    def has_work(self) -> bool:
        return any(rep.has_work() for rep in self.replicas)

    def step(self) -> List:
        """One round on every replica that has work; returns their events."""
        evts: List = []
        for rep in self.replicas:
            if rep.has_work():
                evts.extend(rep.step())
        return evts

    def subscribe(self, fn) -> None:
        """Event tap across all replicas (rids are globally unique, so one
        callback serves the whole fleet)."""
        for rep in self.replicas:
            rep.server.subscribe(fn)

    def run(self, max_wall_s: float = 600.0) -> None:
        """Pump every replica until the fleet drains (or the wall budget /
        a fleet-wide wedge stops it)."""
        t_end = time.perf_counter() + max_wall_s
        stall = 0
        while self.has_work() and time.perf_counter() < t_end:
            self.step()
            if any(rep.progress() == "executed" for rep in self.replicas
                   if rep.has_work()):
                stall = 0
                continue
            stall = stall + 1 if all(rep.stalled() or not rep.has_work()
                                     for rep in self.replicas) else 0
            if stall >= 8:
                break
            time.sleep(1e-3)
        for rep in self.replicas:
            rep.flush()

    def run_open_loop(self, requests: Sequence[Request],
                      prompts: Dict[int, np.ndarray],
                      max_wall_s: float = 300.0) -> Dict:
        """Open-loop replay across the fleet: submit each request at its
        wall-clock arrival offset (routing it then — placement must see the
        directory as it is at arrival time, not at workload build time) and
        pump every replica in between.

        Each replica runs its own engine clock, so arrivals are rebased
        per-placement preserving *lateness*: a request submitted ``d``
        seconds after its scheduled arrival lands with ``arrival = now - d``
        on its replica's clock, keeping queueing-time SLO accounting exactly
        as the single-engine driver measures it."""
        order = sorted(requests, key=lambda r: r.arrival)
        t0 = time.perf_counter()
        i = 0
        t_end = t0 + max_wall_s
        while i < len(order) and time.perf_counter() < t_end:
            now = time.perf_counter() - t0
            while i < len(order) and order[i].arrival <= now:
                r = order[i]
                lateness = now - r.arrival
                prompt = prompts[r.rid]
                idx = self._place(r, np.asarray(prompt, np.int32))
                r.arrival = self.replicas[idx].now() - lateness
                self.handles[r.rid] = self.replicas[idx].submit_request(
                    r, prompt)
                i += 1
            if i == len(order):
                break
            if not self.has_work():
                time.sleep(max(order[i].arrival - (time.perf_counter() - t0),
                               0.0) + 1e-4)
                continue
            self.step()
            if not any(rep.progress() == "executed"
                       for rep in self.replicas):
                time.sleep(1e-3)
        self.run(max_wall_s=max(t_end - time.perf_counter(), 0.0))
        finished = [h for h in self.handles.values()
                    if h.finished and not h.aborted]
        return {
            "handles": self.handles,
            "finished": finished,
            "unfinished": [h for h in self.handles.values()
                           if not h.finished],
            "wall": time.perf_counter() - t0,
        }

    # ---- lifecycle / reporting -----------------------------------------------
    def close(self, drain_s: float = 30.0) -> Dict:
        """Drain and close every replica (each verifies its pages/slots are
        fully reclaimed); returns the aggregated drain report."""
        reports = [rep.close(drain_s) for rep in self.replicas]
        return {
            "drained": all(r["drained"] for r in reports),
            "finished": sum(r["finished"] for r in reports),
            "aborted": sum(r["aborted"] for r in reports),
            "replicas": reports,
        }

    def routing_report(self) -> Dict:
        """Placement summary: per-replica routed counts and token work, the
        max/min work imbalance (the bench's headline metric), spill and
        affinity counters, and the directory's own accounting."""
        work = [max(w, 0) for w in self.work_tokens]
        lo = min(work) if work else 0
        hi = max(work) if work else 0
        return {
            "policy": self.policy,
            "replicas": len(self.replicas),
            "routed": list(self.routed),
            "work_tokens": list(work),
            "imbalance": (hi / lo) if lo > 0 else float("inf") if hi else 1.0,
            "spills": self.spills,
            "affine_hits": self.affine_hits,
            "directory": self.directory.stats(),
        }

    def stats_snapshot(self) -> Dict:
        return {
            "routing": self.routing_report(),
            "replicas": [rep.stats_snapshot() for rep in self.replicas],
        }
