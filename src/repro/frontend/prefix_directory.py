"""Cross-engine prefix directory: which replica holds which frozen prefix.

The per-engine radix index (``BlockAllocator``) names pages by *physical*
parent page id — exact, but meaningless outside its own allocator. The
directory generalises it across engines by re-keying on **hashed
page-granular token chains** (``page_chain_hash``): the chain hash of page k
folds the parent's chain hash with the page's token ids, so equal prompt
prefixes produce equal hashes on every replica and in every process.

The directory is *derived* state: it mirrors each replica's committed-page
set through the allocator's commit/reclaim notifications
(``BlockAllocator.listener``), never the other way around. A hit here is a
*routing hint* — the authoritative match still happens inside the chosen
replica's allocator at admission — so staleness (a reclaim racing a route)
costs a missed hit or a cold prefill, never a correctness failure.

``match(token_ids)`` walks the prompt page by page and reports, per replica,
the longest chain held from the root — the router steers the request to the
deepest holder (prefix affinity) unless that replica is saturated.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Set

from repro.serving.block_allocator import ROOT_CHAIN, page_chain_hash


class _ReplicaListener:
    """Allocator-facing adapter binding one replica id to the directory
    (the ``BlockAllocator.listener`` protocol)."""

    def __init__(self, directory: "PrefixDirectory", replica: int):
        self._dir = directory
        self._replica = replica

    def on_commit(self, chain_hash: bytes, depth: int) -> None:
        self._dir.on_commit(self._replica, chain_hash)

    def on_reclaim(self, chain_hash: bytes) -> None:
        self._dir.on_reclaim(self._replica, chain_hash)


class PrefixDirectory:
    """Shared chain-hash -> holder-replica map with per-replica accounting.

    Thread-safe: in-process replicas notify synchronously from the router's
    pump thread, while HTTP replicas apply polled feed events from whichever
    thread drives the client — a plain lock keeps both paths safe."""

    def __init__(self, page_size: int):
        assert page_size > 0
        self.page_size = page_size
        self._holders: Dict[bytes, Set[int]] = {}
        self._by_replica: Dict[int, Set[bytes]] = {}
        self._lock = threading.Lock()
        # lifetime accounting (the router's BENCH record reads these)
        self.commits = 0
        self.reclaims = 0
        self.lookups = 0
        self.hit_lookups = 0          # lookups that matched >= 1 page
        self.hit_tokens = 0           # tokens steered onto a holding replica

    def listener_for(self, replica: int) -> _ReplicaListener:
        """The ``BlockAllocator.listener`` for one replica's allocator."""
        with self._lock:
            self._by_replica.setdefault(replica, set())
        return _ReplicaListener(self, replica)

    # ---- updates (replica commit/reclaim events) ----------------------------
    def on_commit(self, replica: int, chain_hash: bytes) -> None:
        with self._lock:
            self._holders.setdefault(chain_hash, set()).add(replica)
            self._by_replica.setdefault(replica, set()).add(chain_hash)
            self.commits += 1

    def on_reclaim(self, replica: int, chain_hash: bytes) -> None:
        with self._lock:
            holders = self._holders.get(chain_hash)
            if holders is not None:
                holders.discard(replica)
                if not holders:
                    del self._holders[chain_hash]
            self._by_replica.setdefault(replica, set()).discard(chain_hash)
            self.reclaims += 1

    # ---- queries ------------------------------------------------------------
    def chain_hashes(self, token_ids: Sequence[int],
                     max_tokens: Optional[int] = None) -> List[bytes]:
        """Chain hashes of the whole pages of ``token_ids`` in order (the
        same fold the allocator applies at commit)."""
        limit = len(token_ids) if max_tokens is None else min(
            max_tokens, len(token_ids))
        ps = self.page_size
        out: List[bytes] = []
        h = ROOT_CHAIN
        for k in range(limit // ps):
            h = page_chain_hash(h, token_ids[k * ps:(k + 1) * ps])
            out.append(h)
        return out

    def match(self, token_ids: Sequence[int],
              max_tokens: Optional[int] = None) -> Dict[int, int]:
        """Per-replica longest held prefix of ``token_ids``, in tokens.

        Returns ``{replica: matched_tokens}`` for every replica holding at
        least the first page; a replica's count only extends while it holds
        every page of the chain so far (a deeper page held without its
        prefix is unreachable for reuse and does not count)."""
        chain = self.chain_hashes(token_ids, max_tokens)
        matched: Dict[int, int] = {}
        with self._lock:
            self.lookups += 1
            alive = set(self._holders.get(chain[0], ())) if chain else set()
            depth = 0
            for h in chain:
                holders = self._holders.get(h, set())
                alive &= holders
                if not alive:
                    break
                depth += 1
                for r in alive:
                    matched[r] = depth * self.page_size
            if matched:
                self.hit_lookups += 1
        return matched

    def pages_held(self, replica: int) -> int:
        with self._lock:
            return len(self._by_replica.get(replica, ()))

    def note_routed_hit(self, tokens: int) -> None:
        """Record that a request was steered onto a replica already holding
        ``tokens`` of its prefix (the router's directory-hit accounting)."""
        with self._lock:
            self.hit_tokens += tokens

    def stats(self) -> Dict:
        with self._lock:
            return {
                "page_size": self.page_size,
                "entries": len(self._holders),
                "pages_by_replica": {r: len(hs)
                                     for r, hs in self._by_replica.items()},
                "commits": self.commits,
                "reclaims": self.reclaims,
                "lookups": self.lookups,
                "hit_lookups": self.hit_lookups,
                "hit_rate": self.hit_lookups / max(self.lookups, 1),
                "routed_hit_tokens": self.hit_tokens,
            }
