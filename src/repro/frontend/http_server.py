"""Asyncio HTTP/SSE front door over the streaming serving stack (stdlib-only).

Network transport for :class:`InferenceServer` / :class:`EngineRouter` with
**zero engine changes**: the engine is already single-stepped, so one asyncio
task pumps ``backend.step()`` while request handlers await per-rid event
queues fed by the server's event-subscription tap. Everything — pump, HTTP
parsing, SSE writers — runs on one event loop thread, so no locks guard the
(non-thread-safe) engine.

Endpoints::

    POST   /v1/generate        {"prompt": [ids], "slo_class": "...",
                                "max_output": N, "eos_id": id|null,
                                "stop_ids": [ids]}
        -> text/event-stream; one SSE event per engine event:
           `accepted` (carries the rid for mid-stream cancel), `queued`,
           `admitted`, `first_token` / `token` (token ids; a `token` frame
           carries the round's whole burst as `tokens: [ids]` — speculative
           verify rows emit several ids per round — with `token` kept as the
           first id for pre-batch consumers), `evicted`, and a terminal
           `finished` / `aborted`.
    DELETE /v1/requests/{rid}  -> {"cancelled": bool}  (frees KV pages
                                  mid-prefill or mid-decode)
    GET    /v1/stats           -> EngineStats + cache_info + per-class
                                  metrics (InferenceServer.stats_snapshot /
                                  EngineRouter.stats_snapshot)
    GET    /v1/healthz         -> {"ok": true, "draining": bool}
    GET    /v1/load            -> outstanding-token / class-depth gauges
                                  (the remote router's placement signal)
    GET    /v1/prefix_feed?since=K
                               -> this engine's commit/reclaim chain-hash
                                  stream from K (the remote router mirrors
                                  it into its PrefixDirectory)

SIGINT/SIGTERM triggers graceful drain: stop admitting (503 on generate),
finish in-flight requests up to the drain deadline, abort stragglers with
pages verifiably reclaimed (``backend.close()`` asserts the pools refill),
then exit 0.

    python -m repro.frontend.http_server --port 8763 --replicas 2
"""
from __future__ import annotations

import argparse
import asyncio
import json
import signal
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.engine import EventKind

SSE_HEADERS = (b"HTTP/1.1 200 OK\r\n"
               b"Content-Type: text/event-stream\r\n"
               b"Cache-Control: no-cache\r\n"
               b"Connection: close\r\n\r\n")


class _PrefixFeed:
    """Append-only export of one engine's commit/reclaim chain-hash stream
    (the ``BlockAllocator.listener`` protocol). A remote router polls
    ``/v1/prefix_feed`` and replays this log into its own
    :class:`PrefixDirectory` — the same events an in-process replica would
    deliver synchronously, just batched and late (staleness costs a missed
    routing hit, never correctness)."""

    def __init__(self):
        self.events: List[Tuple[str, str]] = []   # ("c"|"r", hash hex)

    def on_commit(self, chain_hash: bytes, depth: int) -> None:
        self.events.append(("c", chain_hash.hex()))

    def on_reclaim(self, chain_hash: bytes) -> None:
        self.events.append(("r", chain_hash.hex()))

    def since(self, k: int) -> Dict:
        k = max(0, min(k, len(self.events)))
        return {"events": self.events[k:], "next": len(self.events)}


class HttpFrontend:
    """One listening socket over one backend (an :class:`InferenceServer`
    or an :class:`EngineRouter` — both speak submit/cancel/subscribe/step/
    has_work/close/stats_snapshot)."""

    def __init__(self, backend, host: str = "127.0.0.1", port: int = 8763,
                 drain_s: float = 30.0):
        self.backend = backend
        self.host, self.port = host, port
        self.drain_s = drain_s
        self._rid = 0
        self._queues: Dict[int, asyncio.Queue] = {}
        self._stopping = False
        self._stop_event: Optional[asyncio.Event] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        backend.subscribe(self._on_event)
        # single-engine backends export their commit/reclaim stream so a
        # remote router can mirror it; a router backend keeps its own
        # directory and exports nothing.
        self.feed: Optional[_PrefixFeed] = None
        core = getattr(backend, "core", None)
        if core is not None and core.cache_mode == "paged":
            self.feed = _PrefixFeed()
            core.alloc.listener = self.feed

    # ---- engine event fan-in (runs inside backend.step on the loop) ---------
    def _on_event(self, ev) -> None:
        q = self._queues.get(ev.rid)
        if q is not None:
            q.put_nowait(ev)

    # ---- engine pump ---------------------------------------------------------
    async def _pump(self) -> None:
        """The one place the engine advances: alternate ``step()`` with a
        zero-sleep so SSE writers interleave between rounds."""
        while True:
            if self.backend.has_work():
                self.backend.step()
                await asyncio.sleep(0)
            else:
                await asyncio.sleep(0.002)

    # ---- HTTP plumbing -------------------------------------------------------
    @staticmethod
    async def _respond(writer: asyncio.StreamWriter, code: int, obj) -> None:
        body = json.dumps(obj).encode()
        reason = {200: "OK", 404: "Not Found", 400: "Bad Request",
                  503: "Service Unavailable"}.get(code, "OK")
        writer.write(
            f"HTTP/1.1 {code} {reason}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n".encode() + body)
        await writer.drain()
        writer.close()

    @staticmethod
    def _sse(event: str, data: Dict) -> bytes:
        return f"event: {event}\ndata: {json.dumps(data)}\n\n".encode()

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            head = await asyncio.wait_for(reader.readuntil(b"\r\n\r\n"), 10.0)
        except (asyncio.TimeoutError, asyncio.IncompleteReadError,
                ConnectionError):
            writer.close()
            return
        try:
            lines = head.decode("latin1").split("\r\n")
            method, target, _ = lines[0].split(" ", 2)
            headers = {k.strip().lower(): v.strip() for k, v in
                       (l.split(":", 1) for l in lines[1:] if ":" in l)}
            clen = int(headers.get("content-length", "0"))
            body = await reader.readexactly(clen) if clen else b""
            path, _, query = target.partition("?")
            await self._route(method, path, query, body, writer)
        except ConnectionError:
            writer.close()
        except Exception as e:           # malformed request, bad JSON, ...
            try:
                await self._respond(writer, 400, {"error": str(e)})
            except ConnectionError:
                writer.close()

    async def _route(self, method: str, path: str, query: str,
                     body: bytes, writer: asyncio.StreamWriter) -> None:
        if method == "POST" and path == "/v1/generate":
            await self._generate(json.loads(body or b"{}"), writer)
        elif method == "DELETE" and path.startswith("/v1/requests/"):
            rid = int(path.rsplit("/", 1)[1])
            await self._respond(writer, 200,
                                {"rid": rid,
                                 "cancelled": bool(self.backend.cancel(rid))})
        elif method == "GET" and path == "/v1/stats":
            await self._respond(writer, 200, self.backend.stats_snapshot())
        elif method == "GET" and path == "/v1/healthz":
            await self._respond(writer, 200,
                                {"ok": True, "draining": self._stopping})
        elif method == "GET" and path == "/v1/load":
            await self._respond(writer, 200, self._load_info())
        elif method == "GET" and path == "/v1/prefix_feed":
            if self.feed is None:
                await self._respond(writer, 404,
                                    {"error": "no prefix feed (slot mode or "
                                              "router backend)"})
                return
            since = 0
            for kv in query.split("&"):
                if kv.startswith("since="):
                    since = int(kv[6:] or 0)
            await self._respond(writer, 200, self.feed.since(since))
        else:
            await self._respond(writer, 404, {"error": f"{method} {path}"})

    def _load_info(self) -> Dict:
        core = getattr(self.backend, "core", None)
        if core is None:                # router backend: aggregate
            reps = self.backend.replicas
            return {"outstanding_tokens": sum(r.outstanding_tokens()
                                              for r in reps),
                    "replicas": len(reps)}
        return {
            "outstanding_tokens": core.outstanding_tokens(),
            "queue_depth": core.queue_depth,
            "class_depth": [core.class_queue_depth(r) for r in (0, 1, 2)],
            "page_size": getattr(core, "page_size", 0),
        }

    # ---- generate (SSE) ------------------------------------------------------
    async def _generate(self, req: Dict, writer: asyncio.StreamWriter) -> None:
        if self._stopping:
            await self._respond(writer, 503, {"error": "draining"})
            return
        prompt = np.asarray(req["prompt"], np.int32)
        if prompt.ndim != 1 or len(prompt) == 0:
            await self._respond(writer, 400, {"error": "prompt must be a "
                                                       "non-empty id list"})
            return
        rid = self._rid
        self._rid += 1
        # queue registered BEFORE submit: QUEUED fires synchronously inside
        # submit and must not be lost (single loop thread -> no race)
        q: asyncio.Queue = asyncio.Queue()
        self._queues[rid] = q
        try:
            self.backend.submit(
                prompt,
                slo_class=req.get("slo_class", "standard"),
                max_output=int(req.get("max_output", 64)),
                eos_id=req.get("eos_id"),
                stop_ids=tuple(req.get("stop_ids", ())),
                rid=rid)
        except Exception as e:
            del self._queues[rid]
            await self._respond(writer, 503, {"error": str(e)})
            return
        writer.write(SSE_HEADERS)
        writer.write(self._sse("accepted", {"rid": rid}))
        n_tokens = 0
        pending = None
        try:
            await writer.drain()
            while True:
                if pending is not None:
                    ev, pending = pending, None
                else:
                    ev = await asyncio.wait_for(
                        q.get(), timeout=float(req.get("max_wall_s", 600.0)))
                data: Dict = {"rid": rid, "t": round(ev.t, 6)}
                if ev.kind in (EventKind.FIRST_TOKEN, EventKind.TOKEN):
                    # coalesce the round's burst: a speculative verify row
                    # emits several TOKEN events per engine round, and one
                    # SSE frame should carry the whole burst. `token` stays
                    # the first id for pre-batch consumers.
                    toks = [int(ev.token)]
                    if ev.kind is EventKind.TOKEN:
                        while True:
                            try:
                                nxt = q.get_nowait()
                            except asyncio.QueueEmpty:
                                break
                            if nxt.kind is EventKind.TOKEN:
                                toks.append(int(nxt.token))
                            else:
                                pending = nxt
                                break
                    data["token"] = toks[0]
                    data["tokens"] = toks
                    n_tokens += len(toks)
                if ev.kind in (EventKind.FINISHED, EventKind.ABORTED):
                    data["reason"] = (ev.reason or "length"
                                      if ev.kind is EventKind.FINISHED
                                      else "aborted")
                    data["n_tokens"] = n_tokens
                writer.write(self._sse(ev.kind.name.lower(), data))
                await writer.drain()
                if ev.kind in (EventKind.FINISHED, EventKind.ABORTED):
                    break
        except asyncio.TimeoutError:
            writer.write(self._sse("error", {"rid": rid,
                                             "error": "timeout"}))
            self.backend.cancel(rid)
        except (ConnectionError, asyncio.CancelledError):
            # client went away mid-stream: free its KV pages now
            self.backend.cancel(rid)
            raise
        finally:
            self._queues.pop(rid, None)
            writer.close()

    # ---- lifecycle -----------------------------------------------------------
    async def serve_forever(self) -> Dict:
        """Listen, pump, and block until SIGINT/SIGTERM (or ``request_stop``);
        then drain gracefully and return the backend's drain report."""
        self._stop_event = asyncio.Event()
        server = await asyncio.start_server(self._handle, self.host,
                                            self.port)
        self.port = server.sockets[0].getsockname()[1]
        loop = asyncio.get_running_loop()
        self._loop = loop
        for sig in (signal.SIGINT, signal.SIGTERM):
            try:
                loop.add_signal_handler(sig, self._stop_event.set)
            except (NotImplementedError, RuntimeError):
                pass      # non-unix, or loop not on the main thread (tests)
        pump = asyncio.create_task(self._pump())
        print(f"listening on http://{self.host}:{self.port}", flush=True)
        await self._stop_event.wait()

        # graceful drain: no new admissions, let the pump finish in-flight
        # work to the deadline, then abort stragglers with pages reclaimed.
        self._stopping = True
        server.close()
        await server.wait_closed()
        deadline = loop.time() + self.drain_s
        while self.backend.has_work() and loop.time() < deadline:
            await asyncio.sleep(0.01)
        pump.cancel()
        report = self.backend.close(
            drain_s=max(deadline - loop.time(), 0.0))
        # let straggler ABORTED events reach any SSE writer still attached
        await asyncio.sleep(0.05)
        print(f"drained: {json.dumps(report, default=str)}", flush=True)
        return report

    def request_stop(self) -> None:
        """Trigger the same graceful drain as SIGINT (thread-safe: tests
        drive the server from a sibling thread)."""
        if self._stop_event is not None:
            self._loop.call_soon_threadsafe(self._stop_event.set)


def build_backend(arch: str = "llama3.2-3b", smoke: bool = True,
                  replicas: int = 1, policy: str = "prefix-affine",
                  cache_mode: str = "paged", kv_tokens: int = 4096,
                  page_size: int = 16, max_budget: int = 256,
                  prefix_cache: bool = True, max_output_default: int = 64,
                  **engine_kw):
    """An :class:`InferenceServer` (1 replica) or :class:`EngineRouter`
    (N replicas) ready to sit behind :class:`HttpFrontend`. Replicas share
    ``seed=0`` params, so greedy tokens depend only on the prompt and any
    placement yields bit-identical streams."""
    from repro.configs import get_config
    from repro.core import SlidingServeScheduler
    from repro.frontend.router import EngineRouter, LocalReplica
    from repro.serving.server import InferenceServer

    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()

    def mk_server():
        return InferenceServer.build(
            cfg,
            scheduler=SlidingServeScheduler(max_budget=max_budget,
                                            max_iter_time=5.0),
            cache_mode=cache_mode, max_slots=4, max_len=512,
            kv_capacity_tokens=kv_tokens, page_size=page_size,
            prefix_cache=prefix_cache, **engine_kw)

    if replicas <= 1:
        return mk_server()
    return EngineRouter([LocalReplica(i, mk_server())
                         for i in range(replicas)], policy=policy)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="stdlib HTTP/SSE front door over the serving stack")
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8763,
                    help="0 picks a free port (printed on the banner line)")
    ap.add_argument("--replicas", type=int, default=1,
                    help=">1 runs an in-process prefix-affine router")
    ap.add_argument("--policy", default="prefix-affine",
                    choices=["prefix-affine", "round-robin"])
    ap.add_argument("--cache-mode", default="paged",
                    choices=["auto", "slot", "paged"])
    ap.add_argument("--kv-tokens", type=int, default=4096)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-budget", type=int, default=256)
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative drafts per decode round (0 = off)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--sample-seed", type=int, default=0)
    ap.add_argument("--drain-s", type=float, default=30.0,
                    help="graceful-shutdown drain deadline on SIGINT")
    args = ap.parse_args(argv)

    backend = build_backend(
        arch=args.arch, smoke=args.smoke, replicas=args.replicas,
        policy=args.policy, cache_mode=args.cache_mode,
        kv_tokens=args.kv_tokens, page_size=args.page_size,
        max_budget=args.max_budget, prefix_cache=args.prefix_cache,
        spec_k=args.spec_k, temperature=args.temperature, top_k=args.top_k,
        sample_seed=args.sample_seed)
    frontend = HttpFrontend(backend, host=args.host, port=args.port,
                            drain_s=args.drain_s)
    asyncio.run(frontend.serve_forever())
    return 0


if __name__ == "__main__":
    sys.exit(main())
