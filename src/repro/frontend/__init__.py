"""Network front door: HTTP/SSE transport + prefix-affine multi-engine router.

The serving stack below this package is a single engine behind an in-process
streaming API. This package is the production edge on top of it:

* ``http_server`` — asyncio HTTP/SSE transport (stdlib-only) over the
  ``submit / tokens / cancel`` API; the engine needs no changes because
  ``EngineCore.step()`` is already single-stepped.
* ``router`` — N engine replicas behind one submit surface, with
  prefix-affine, load-aware, SLO-class-aware dispatch.
* ``prefix_directory`` — the cross-engine generalisation of the per-engine
  radix index: which replica holds which frozen page chain, keyed on hashed
  page-granular token chains and updated from each replica's commit/reclaim
  events.
* ``client`` — thin blocking HTTP client (SSE streaming, cancel, stats);
  also the ``HttpReplica`` adapter so the same router class can front N
  remote HTTP backends instead of in-process engines.
"""
from repro.frontend.prefix_directory import PrefixDirectory  # noqa: F401
from repro.frontend.router import EngineRouter, LocalReplica  # noqa: F401

_LAZY = {
    # http_server must not be imported eagerly: `python -m
    # repro.frontend.http_server` imports this package first, and an eager
    # import would shadow the module runpy is about to execute
    "HttpFrontend": "repro.frontend.http_server",
    "build_backend": "repro.frontend.http_server",
    "EngineHttpClient": "repro.frontend.client",
    "HttpReplica": "repro.frontend.client",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
