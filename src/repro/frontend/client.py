"""Thin blocking HTTP client for the front door (stdlib ``http.client``).

Two layers:

* :class:`EngineHttpClient` + :class:`HttpStreamHandle` — the caller-facing
  client: ``generate()`` POSTs a prompt and returns a handle whose
  ``tokens()`` iterator parses the SSE stream incrementally (the server
  closes the connection after the terminal event, so EOF == end of stream);
  ``cancel()`` DELETEs mid-stream on a second connection, freeing the
  request's KV pages remotely.

* :class:`HttpReplica` — the router-facing adapter: the same protocol
  :class:`LocalReplica` speaks (submit_request / cancel / load gauges /
  directory hookup), but over HTTP, so one ``EngineRouter`` fronts N remote
  backends. The remote engine pumps itself (the HTTP server owns its pump
  task), so ``step()`` here only mirrors the backend's prefix feed into the
  router's directory; load gauges come from ``GET /v1/load`` with a short
  cache so placement doesn't issue one HTTP round-trip per gauge read.
"""
from __future__ import annotations

import http.client
import json
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


class HttpStreamHandle:
    """One in-flight ``/v1/generate`` stream. Mirrors the in-process
    :class:`StreamHandle` surface the drivers consume: ``tokens()`` /
    ``result()`` / ``cancel()`` / ``collected`` / ``finished`` /
    ``finish_reason`` / ``aborted``."""

    def __init__(self, client: "EngineHttpClient",
                 resp: http.client.HTTPResponse):
        self._client = client
        self._resp = resp
        self.rid: int = -1
        self.collected: List[int] = []
        self.finished = False
        self.finish_reason = ""
        self.first_token_t: Optional[float] = None
        self.events: List[Tuple[str, Dict]] = []
        # the `accepted` preamble carries the rid (needed for cancel before
        # any token arrives)
        name, data = self._read_event()
        assert name == "accepted", f"expected accepted, got {name}"
        self.rid = int(data["rid"])

    # ---- SSE parsing ---------------------------------------------------------
    def _read_event(self) -> Tuple[Optional[str], Dict]:
        """Next SSE event (blocking); ``(None, {})`` at EOF."""
        name, payload = None, ""
        while True:
            raw = self._resp.readline()
            if not raw:                       # server closed: stream over
                return None, {}
            line = raw.decode().rstrip("\n").rstrip("\r")
            if not line:                      # blank line ends one event
                if name is not None:
                    return name, json.loads(payload or "{}")
                continue
            if line.startswith("event:"):
                name = line[6:].strip()
            elif line.startswith("data:"):
                payload += line[5:].strip()

    def _apply(self, name: str, data: Dict) -> List[int]:
        self.events.append((name, data))
        if name in ("first_token", "token"):
            if name == "first_token":
                self.first_token_t = data.get("t")
            # a `token` frame carries the round's burst as `tokens: [ids]`
            # (speculative rounds emit several); older servers send only the
            # single `token` field.
            toks = [int(t) for t in data.get("tokens", [data["token"]])]
            self.collected.extend(toks)
            return toks
        if name in ("finished", "aborted", "error"):
            self.finished = True
            self.finish_reason = ("aborted" if name != "finished"
                                  else data.get("reason", "length"))
        return []

    # ---- client surface ------------------------------------------------------
    @property
    def aborted(self) -> bool:
        return self.finish_reason == "aborted"

    def tokens(self) -> Iterator[int]:
        """Yield output ids as SSE events arrive; returns at the terminal
        event (finished / aborted / connection close)."""
        while not self.finished:
            name, data = self._read_event()
            if name is None:
                self.finished = True
                self.finish_reason = self.finish_reason or "aborted"
                break
            for tok in self._apply(name, data):
                yield tok
        self._resp.close()

    def result(self) -> List[int]:
        for _ in self.tokens():
            pass
        return list(self.collected)

    def cancel(self) -> bool:
        """Cancel server-side (second connection; this stream then receives
        its terminal `aborted` event)."""
        return self._client.cancel(self.rid)


class EngineHttpClient:
    """Blocking JSON/SSE client for one front-door address."""

    def __init__(self, host: str = "127.0.0.1", port: int = 8763,
                 timeout: float = 120.0):
        self.host, self.port, self.timeout = host, port, timeout

    def _conn(self) -> http.client.HTTPConnection:
        return http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)

    def _json(self, method: str, path: str, body: Optional[Dict] = None
              ) -> Dict:
        conn = self._conn()
        try:
            conn.request(method, path,
                         body=None if body is None else json.dumps(body),
                         headers={"Content-Type": "application/json"})
            resp = conn.getresponse()
            out = json.loads(resp.read() or b"{}")
            if resp.status >= 400:
                raise RuntimeError(f"{method} {path} -> {resp.status}: "
                                   f"{out.get('error', out)}")
            return out
        finally:
            conn.close()

    # ---- API -----------------------------------------------------------------
    def generate(self, prompt: Sequence[int], slo_class: str = "standard",
                 max_output: int = 64, eos_id: Optional[int] = None,
                 stop_ids: Sequence[int] = ()) -> HttpStreamHandle:
        conn = self._conn()
        conn.request("POST", "/v1/generate", body=json.dumps({
            "prompt": [int(t) for t in prompt],
            "slo_class": slo_class, "max_output": int(max_output),
            "eos_id": eos_id, "stop_ids": [int(t) for t in stop_ids],
        }), headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        if resp.status != 200:
            err = json.loads(resp.read() or b"{}")
            conn.close()
            raise RuntimeError(f"generate -> {resp.status}: "
                               f"{err.get('error', err)}")
        return HttpStreamHandle(self, resp)

    def cancel(self, rid: int) -> bool:
        return bool(self._json("DELETE", f"/v1/requests/{rid}")["cancelled"])

    def stats(self) -> Dict:
        return self._json("GET", "/v1/stats")

    def healthz(self) -> Dict:
        return self._json("GET", "/v1/healthz")

    def load(self) -> Dict:
        return self._json("GET", "/v1/load")

    def prefix_feed(self, since: int = 0) -> Dict:
        return self._json("GET", f"/v1/prefix_feed?since={since}")

    def wait_ready(self, deadline_s: float = 30.0) -> None:
        t_end = time.perf_counter() + deadline_s
        while time.perf_counter() < t_end:
            try:
                if self.healthz().get("ok"):
                    return
            except (OSError, RuntimeError):
                pass
            time.sleep(0.05)
        raise TimeoutError(f"server {self.host}:{self.port} not ready")


class HttpReplica:
    """Router-facing adapter over one remote front door — the same protocol
    as :class:`LocalReplica`, minus local pumping (the remote server pumps
    itself). The router's rid space and the remote's are independent:
    ``submit_request`` records the router-rid -> remote-rid mapping and
    cancels translate through it."""

    LOAD_TTL_S = 0.05      # gauge cache: at most one /v1/load per placement

    def __init__(self, index: int, client: EngineHttpClient):
        self.index = index
        self.client = client
        self.cost_per_token = 2e-4       # prior; no local step timing
        self._directory = None
        self._feed_pos = 0
        self._load: Optional[Dict] = None
        self._load_t = -1.0
        self._remote_rid: Dict[int, int] = {}
        self._page_size: Optional[int] = None

    # ---- directory hookup ----------------------------------------------------
    @property
    def page_size(self) -> int:
        if self._page_size is None:
            self._page_size = int(self._load_info().get("page_size", 0))
        return self._page_size

    @property
    def paged(self) -> bool:
        return self.page_size > 0

    def attach_directory(self, directory) -> None:
        self._directory = directory

    def poll_feed(self) -> int:
        """Mirror the backend's commit/reclaim stream into the router's
        directory; returns how many events were applied."""
        if self._directory is None or not self.paged:
            return 0
        try:
            feed = self.client.prefix_feed(since=self._feed_pos)
        except (OSError, RuntimeError):
            return 0
        for op, hex_hash in feed["events"]:
            h = bytes.fromhex(hex_hash)
            if op == "c":
                self._directory.on_commit(self.index, h)
            else:
                self._directory.on_reclaim(self.index, h)
        applied = feed["next"] - self._feed_pos
        self._feed_pos = feed["next"]
        return applied

    # ---- submit / cancel -----------------------------------------------------
    def submit_request(self, req, prompt: Sequence[int]) -> HttpStreamHandle:
        h = self.client.generate(
            np.asarray(prompt, np.int32).tolist(),
            slo_class=req.slo_class, max_output=req.max_output,
            eos_id=req.eos_id, stop_ids=req.stop_ids)
        self._remote_rid[req.rid] = h.rid
        return h

    def cancel(self, rid: int) -> bool:
        remote = self._remote_rid.get(rid)
        if remote is None:
            return False
        try:
            return self.client.cancel(remote)
        except (OSError, RuntimeError):
            return False

    # ---- pumping (remote pumps itself) ---------------------------------------
    def has_work(self) -> bool:
        return self._load_info().get("outstanding_tokens", 0) > 0

    def step(self) -> List:
        self.poll_feed()
        return []

    def progress(self) -> str:
        return "executed" if self.has_work() else "idle"

    def stalled(self) -> bool:
        return False

    def flush(self) -> None:
        self.poll_feed()

    # ---- router gauges -------------------------------------------------------
    def _load_info(self) -> Dict:
        now = time.perf_counter()
        if self._load is None or now - self._load_t > self.LOAD_TTL_S:
            try:
                self._load = self.client.load()
                self._load_t = now
            except (OSError, RuntimeError):
                self._load = self._load or {}
        return self._load

    def outstanding_tokens(self) -> int:
        return int(self._load_info().get("outstanding_tokens", 0))

    def load_cost(self) -> float:
        return self.outstanding_tokens() * self.cost_per_token

    def class_ahead(self, max_rank: int) -> int:
        depth = self._load_info().get("class_depth")
        if not depth:
            return 0
        return int(depth[min(max_rank, len(depth) - 1)])

    def now(self) -> float:
        return time.perf_counter()

    # ---- lifecycle / reporting -----------------------------------------------
    def close(self, drain_s: float = 30.0) -> Dict:
        """The remote server owns its own drain (SIGINT); nothing to do from
        the client side but report what finished through this adapter."""
        self.poll_feed()
        return {"drained": True, "finished": 0, "aborted": 0}

    def stats_snapshot(self) -> Dict:
        return self.client.stats()
