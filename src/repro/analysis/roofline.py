"""Roofline terms from compiled dry-run artifacts (no real hardware).

Per (arch x shape x mesh) cell:

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``compiled.cost_analysis()`` reports *per-device* flops/bytes, so the chip
division is already folded in. collective_bytes is parsed from the compiled
HLO text: every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute result shape, with while-loop bodies multiplied by their
trip count (XLA's static analysis counts a loop body once; trip counts are
recovered from the loop-condition comparison constants).

For exact flops/bytes the roofline pass lowers the cell with layer scans
*unrolled* (RunCtx.unroll_layers) — the dry-run pass/fail still uses the
scanned program. Residual undercount: the Mamba/sLSTM time-step scans
(O(S*d*n) VPU work, < 0.5% of their layers' FLOPs) — accounted analytically
in MODEL_FLOPS, noted per cell.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# TPU v5e constants (per chip).
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of 'f32[512,1024]{1,0}' or a (tuple, of, shapes)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its lines (flat HLO text parser)."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{$", stripped)
        if m is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\{$", stripped)
        if m and stripped.endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Recover the loop bound from the condition's comparison constant."""
    consts = []
    for ln in cond_lines:
        if "compare(" in ln or "constant(" in ln:
            consts += [int(c) for c in re.findall(r"constant\((\d+)\)", ln)]
    return max(consts) if consts else 1


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: float
    by_kind: Dict[str, float]
    num_ops: int


def collective_bytes(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)

    def comp_bytes(lines: List[str]) -> Tuple[float, Dict[str, float], int]:
        total, by_kind, n = 0.0, {}, 0
        for ln in lines:
            for kind in _COLLECTIVES:
                if re.search(rf"= [^=]*\b{kind}(-start|-done)?\(", ln):
                    if f"{kind}-done" in ln:
                        continue  # counted at -start
                    shape = ln.split("=", 1)[1].split(kind)[0]
                    b = _shape_bytes(shape)
                    total += b
                    by_kind[kind] = by_kind.get(kind, 0.0) + b
                    n += 1
                    break
        return total, by_kind, n

    # find while loops anywhere: body/condition computation names + trip count
    body_mult: Dict[str, int] = {}
    for name, lines in comps.items():
        for ln in lines:
            if " while(" in ln or "= while(" in ln or re.search(r"\bwhile\(", ln):
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                if mb:
                    tc = _trip_count(comps.get(mc.group(1), [])) if mc else 1
                    body_mult[mb.group(1)] = max(body_mult.get(mb.group(1), 1), tc)

    total, by_kind, num = 0.0, {}, 0
    for name, lines in comps.items():
        t, bk, n = comp_bytes(lines)
        mult = body_mult.get(name, 1)
        total += t * mult
        num += n
        for k, v in bk.items():
            by_kind[k] = by_kind.get(k, 0.0) + v * mult
    return CollectiveStats(total_bytes=total, by_kind=by_kind, num_ops=num)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float            # 6*N*D (or 6*N_active*D) global
    useful_ratio: float           # MODEL_FLOPS / (HLO_FLOPs * devices)
    peak_fraction: float          # model-flops utilization at the bound
    memory_per_device_gb: float
    notes: str = ""

    def row(self) -> str:
        return (f"{self.arch},{self.shape},{self.mesh},"
                f"{self.flops_per_device:.3e},{self.bytes_per_device:.3e},"
                f"{self.collective_bytes_per_device:.3e},"
                f"{self.t_compute * 1e3:.3f},{self.t_memory * 1e3:.3f},"
                f"{self.t_collective * 1e3:.3f},{self.bottleneck},"
                f"{self.useful_ratio:.3f},{self.peak_fraction:.3f},"
                f"{self.memory_per_device_gb:.2f}")


def analyze(arch: str, shape_name: str, mesh_name: str, *,
            cost: dict, hlo_text: str, num_devices: int,
            model_flops: float, memory_bytes_per_device: float,
            notes: str = "") -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    # HLO text is the per-device SPMD program -> already per device
    t_comp = flops / PEAK_FLOPS
    t_mem = byts / HBM_BW
    t_coll = coll.total_bytes / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    total_hlo_flops = flops * num_devices
    useful = model_flops / total_hlo_flops if total_hlo_flops else 0.0
    t_bound = max(terms.values())
    ideal = model_flops / (num_devices * PEAK_FLOPS)
    peak_fraction = ideal / t_bound if t_bound > 0 else 0.0
    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=coll.total_bytes,
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_ratio=useful, peak_fraction=peak_fraction,
        memory_per_device_gb=memory_bytes_per_device / 1e9, notes=notes)


def analytic_memory_bytes(cell_inputs, cfg, shape, n_dp: int,
                          accum: int = 1) -> dict:
    """Exact per-device bytes for all inputs (params/opt/cache, from their
    shard shapes) + an activation/workspace estimate.

    Needed because the CPU XLA pipeline does not run the TPU
    HloRematerialization/scheduling passes that enforce HBM limits — its temp
    arena hoists loop-invariant converts across whole saved-activation stacks
    and so structurally overestimates a TPU's peak (observed 2-4x). The
    analytic activation model is the standard accounting: saved layer inputs
    (remat-full) for one microbatch + a working set of ~6 layer tensors.
    """
    import numpy as np
    import jax

    args = 0
    for leaf in jax.tree.leaves(cell_inputs):
        if hasattr(leaf, "sharding") and leaf.sharding is not None:
            shp = leaf.sharding.shard_shape(leaf.shape)
        else:
            shp = leaf.shape
        args += int(np.prod(shp)) * leaf.dtype.itemsize if shp else leaf.dtype.itemsize

    d = cfg.d_model
    layers = cfg.num_layers + (cfg.num_encoder_layers if cfg.enc_dec else 0)
    b_loc = max(shape.global_batch // n_dp, 1)
    if shape.kind == "train":
        b_micro = max(b_loc // accum, 1)
        saved = layers * b_micro * shape.seq_len * d * 2          # bf16 carries
        work = 8 * b_micro * shape.seq_len * d * 4                # bwd tensors
        ce = 2 * b_micro * (shape.seq_len // 16) * cfg.vocab_size * 4
        act = saved + work + ce
    elif shape.kind == "prefill":
        act = 6 * b_loc * shape.seq_len * d * 4
    else:
        act = 6 * b_loc * 1 * d * 4 + 2 * b_loc * cfg.vocab_size * 4
    return {"args_bytes": args, "activation_bytes": act,
            "total_bytes": args + act}


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D forward-only; MoE uses active
    params. D = tokens processed by the step."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n_active * tokens
