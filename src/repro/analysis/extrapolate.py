"""Exact roofline accounting by two-point layer extrapolation.

XLA's cost analysis counts a while-loop body once, and fully unrolling a
61-layer model is compile-prohibitive on this container. Since the layer
stack is homogeneous (one repeating period per stack; dense prefixes and
embed/head/loss/optimizer are rep-independent "outer" work), per-device
cost is affine in the rep count R:

    cost(R) = outer + R * body

Two small *unrolled* probe compiles at R=1 and R=2 recover both terms:

    body = cost(2) - cost(1);     cost(R) = cost(1) + (R - 1) * body

This is exact for FLOPs, bytes-accessed and collective bytes (same mesh and
shardings in the probes). Residual approximation: Mamba/sLSTM time-step
scans stay scans inside the probes (body counted once) — their FLOPs are
O(S*d_inner*d_state), < 0.5% of the owning layer, noted per cell.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax

from repro.analysis import roofline as rl
from repro.configs import get_config
from repro.configs.base import SHAPES


def _probe_cfg(cfg, reps: int):
    """A config with ``reps`` repetitions of the main-stack period."""
    layers = cfg.first_k_dense + cfg.period * reps
    changes = {"num_layers": layers}
    if cfg.enc_dec:
        changes["num_encoder_layers"] = reps
    return dataclasses.replace(cfg, **changes)


def _probe_cost(arch: str, shape_name: str, mesh, cfg, fsdp: bool,
                wide_dp: bool = False) -> Dict:
    import repro.launch.steps as steps
    orig = steps.make_rctx

    def unrolled(c, m, **kw):
        r = orig(c, m, **kw)
        # bigger attention tiles: 4x fewer unrolled tile pairs (identical
        # FLOPs/bytes, much faster CPU compile of the probe)
        blk = max(r.block_q, 2048) if kw.get("seq_len", 0) >= 32768 else r.block_q
        return dataclasses.replace(r, unroll_layers=True, block_q=blk, block_k=blk)

    steps.make_rctx = unrolled
    try:
        cell = steps.build_cell(arch, shape_name, mesh, fsdp=fsdp,
                                cfg_override=cfg, wide_dp=wide_dp)
    finally:
        steps.make_rctx = orig
    compiled = jax.jit(cell.fn, donate_argnums=cell.donate).lower(*cell.inputs).compile()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else (ca or {})
    coll = rl.collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": coll.total_bytes,
    }


def extrapolated_cost(arch: str, shape_name: str, mesh, fsdp: bool = False,
                      wide_dp: bool = False) -> Dict:
    """Per-device (flops, bytes, collective bytes) for the full-depth cell."""
    cfg = get_config(arch)
    main_reps = cfg.num_pattern_reps
    c1 = _probe_cost(arch, shape_name, mesh, _probe_cfg(cfg, 1), fsdp, wide_dp)
    c2 = _probe_cost(arch, shape_name, mesh, _probe_cfg(cfg, 2), fsdp, wide_dp)
    out = {}
    for k in ("flops", "bytes", "coll"):
        body = c2[k] - c1[k]
        out[k] = c1[k] + (main_reps - 1) * body
        out[f"{k}_body"] = body
        out[f"{k}_outer"] = c1[k] - body
    out["reps"] = main_reps
    out["probe1"] = c1
    out["probe2"] = c2
    return out
