"""Launchers: production mesh, per-arch sharding rules, multi-pod dry-run."""
