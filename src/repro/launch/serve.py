"""Serving launcher: the streaming InferenceServer on a real model.

On this container it serves reduced configs on CPU; on TPU the same entry
point builds the production mesh and shards the step functions (the engine
loop is identical — see repro/serving/engine.py). Requests are submitted
through the online API at their arrival times (open-loop) and tokens stream
back through per-request handles.

    python -m repro.launch.serve --arch llama3.2-3b --requests 8
    python -m repro.launch.serve --no-smoke --slo-class interactive ...
    REPRO_FORCE_MESH=2x4 python -m repro.launch.serve --cache-mode paged
    python -m repro.launch.serve --mesh 2x4 ...   # same thing, explicit

``--mesh``/``REPRO_FORCE_MESH`` (the shared helper in ``launch/mesh.py``)
runs the paged executor under jit + shard_map: KV page pools shard attention
heads on the ``model`` axis (or fall back to sequence-sharded attention),
while the scheduler stack and all host state stay mesh-oblivious.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.core import SlidingServeScheduler
from repro.launch.mesh import add_mesh_argument, make_serving_mesh
from repro.serving.engine import EngineCore
from repro.serving.request import Request
from repro.serving.server import SLO_CLASSES, InferenceServer
from repro.serving.workloads import run_open_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--qps", type=float, default=2.0)
    # --smoke/--no-smoke boolean pair (a bare store_true with default=True
    # made the full-size configs unreachable from the CLI)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reduce the model config for CPU smoke runs "
                         "(--no-smoke serves the full-size architecture)")
    ap.add_argument("--max-budget", type=int, default=512)
    ap.add_argument("--slo-class", default="standard",
                    choices=sorted(SLO_CLASSES),
                    help="named tenant class (ttft/tbt SLO pair) submitted "
                         "requests run under")
    ap.add_argument("--cache-mode", default="auto",
                    choices=["auto", "slot", "paged"],
                    help="paged = block-table KV (production layout); "
                         "slot = contiguous rows (recurrent/MLA archs)")
    ap.add_argument("--kv-tokens", type=int, default=4096,
                    help="paged KV capacity in tokens")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reuse frozen KV pages across requests sharing a "
                         "token prefix (paged mode; greedy tokens are "
                         "bit-identical either way)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft up to K tokens per "
                         "decode-eligible request per round (paged mode; "
                         "n-gram prompt-lookup drafter; greedy tokens are "
                         "bit-identical to --spec-k 0)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k sampling cutoff (0 = full vocabulary)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="PRNG seed for non-greedy sampling (runs are "
                         "deterministic per seed)")
    ap.add_argument("--serve-http", action="store_true",
                    help="expose the server over HTTP/SSE instead of "
                         "replaying a synthetic workload (SIGINT drains "
                         "gracefully; see repro.frontend.http_server)")
    ap.add_argument("--port", type=int, default=8763,
                    help="HTTP port for --serve-http (0 picks a free one)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="with --serve-http: >1 runs N engine replicas "
                         "behind the prefix-affine router")
    add_mesh_argument(ap)
    args = ap.parse_args(argv)

    if args.serve_http:
        # the network front door owns engine construction (it builds N
        # replicas for the router); mesh serving stays on the in-process path
        import asyncio

        from repro.frontend.http_server import HttpFrontend, build_backend
        backend = build_backend(
            arch=args.arch, smoke=args.smoke, replicas=args.replicas,
            cache_mode=args.cache_mode, kv_tokens=args.kv_tokens,
            page_size=args.page_size, max_budget=args.max_budget,
            prefix_cache=args.prefix_cache, spec_k=args.spec_k,
            temperature=args.temperature, top_k=args.top_k,
            sample_seed=args.sample_seed)
        frontend = HttpFrontend(backend, port=args.port)
        asyncio.run(frontend.serve_forever())
        return None

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_serving_mesh(args.mesh)
    sched = SlidingServeScheduler(max_budget=args.max_budget, max_iter_time=2.0)
    core = EngineCore(cfg, sched, cache_mode=args.cache_mode,
                      max_slots=4, max_len=512,
                      kv_capacity_tokens=args.kv_tokens,
                      page_size=args.page_size, mesh=mesh,
                      prefix_cache=args.prefix_cache, spec_k=args.spec_k,
                      temperature=args.temperature, top_k=args.top_k,
                      sample_seed=args.sample_seed)
    server = InferenceServer(core)
    if core.mesh is not None:
        print(core.shard_banner())
    slo = SLO_CLASSES[args.slo_class]
    rng = np.random.default_rng(0)
    inter = rng.exponential(1.0 / args.qps, args.requests)
    arrivals = np.cumsum(inter)
    reqs = [Request(rid=i, arrival=float(arrivals[i]),
                    prompt_len=int(rng.integers(16, 128)),
                    max_output=int(rng.integers(4, 12)),
                    ttft_slo=slo.ttft_slo, tbt_slo=slo.tbt_slo,
                    slo_class=slo.name)
            for i in range(args.requests)]
    out = run_open_loop(server, reqs, max_wall_s=300.0)
    st = core.stats
    print(f"finished {len(out['finished'])}/{len(reqs)} "
          f"[{core.cache_mode} cache, slo={args.slo_class}]; "
          f"iterations={st.iterations} "
          f"max_concurrency={st.max_concurrency} evictions={st.evictions} "
          f"wall={out['wall']:.1f}s")
    if core.spec_k:
        si = core.spec_info()
        print(f"speculation: acceptance {si['acceptance_rate']:.0%} "
              f"({si['accepted_tokens']}/{si['draft_tokens']} drafts), "
              f"{si['tokens_per_verify_row']:.2f} tokens/verify row")
    if core.cache_mode == "paged" and core.prefix_cache:
        ci = core.cache_info()
        print(f"prefix cache: hit {ci['hit_tokens']}/{ci['prompt_tokens']} "
              f"prompt tokens ({ci['hit_rate']:.0%}), "
              f"{ci['cached_pages']} pages cached")
    for h in out["finished"]:
        r = h.request
        print(f"  req {r.rid}: ttft={(r.first_token_time - r.arrival):.2f}s "
              f"out={h.collected}")
    return out


if __name__ == "__main__":
    main()
