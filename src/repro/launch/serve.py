"""Serving launcher: the SlidingServe engine on a real model.

On this container it serves reduced configs on CPU; on TPU the same entry
point builds the production mesh and shards the step functions (the engine
loop is identical — see repro/serving/engine.py).

    python -m repro.launch.serve --arch llama3.2-3b --requests 8
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.core import SlidingServeScheduler
from repro.serving.engine import ServingEngine
from repro.serving.request import Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--qps", type=float, default=2.0)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--max-budget", type=int, default=512)
    ap.add_argument("--cache-mode", default="auto",
                    choices=["auto", "slot", "paged"],
                    help="paged = block-table KV (production layout); "
                         "slot = contiguous rows (recurrent/MLA archs)")
    ap.add_argument("--kv-tokens", type=int, default=4096,
                    help="paged KV capacity in tokens")
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    sched = SlidingServeScheduler(max_budget=args.max_budget, max_iter_time=2.0)
    engine = ServingEngine(cfg, sched, cache_mode=args.cache_mode,
                           max_slots=4, max_len=512,
                           kv_capacity_tokens=args.kv_tokens,
                           page_size=args.page_size)
    rng = np.random.default_rng(0)
    inter = rng.exponential(1.0 / args.qps, args.requests)
    arrivals = np.cumsum(inter)
    reqs = [Request(rid=i, arrival=float(arrivals[i]),
                    prompt_len=int(rng.integers(16, 128)),
                    max_output=int(rng.integers(4, 12)),
                    ttft_slo=30.0, tbt_slo=30.0)
            for i in range(args.requests)]
    out = engine.serve(reqs, max_wall_s=300.0)
    st = out["stats"]
    print(f"finished {len(out['finished'])}/{len(reqs)} "
          f"[{engine.cache_mode} cache]; iterations={st.iterations} "
          f"max_concurrency={st.max_concurrency} evictions={st.evictions} "
          f"wall={out['wall']:.1f}s")
    for r in out["finished"]:
        print(f"  req {r.rid}: ttft={(r.first_token_time - r.arrival):.2f}s "
              f"out={out['outputs'][r.rid]}")


if __name__ == "__main__":
    main()
