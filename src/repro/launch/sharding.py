"""Per-arch PartitionSpec rules: params, optimizer state, caches, inputs.

Rules are name/shape-driven over the param pytree (Megatron-style TP on the
``model`` axis, DP over (``pod``, ``data``)):

* column-parallel: attention q/k/v, MLP up/gate, Mamba in-proj, MLA q_b/kv_b
  (output-feature dim on ``model``);
* row-parallel: attention/MLP/Mamba output projections (input-feature dim on
  ``model``);
* expert-parallel: MoE expert stacks sharded on the expert dim;
* vocab-parallel embedding / LM head;
* small tensors (norms, biases, routers, MLA latent down-projs) replicated.

KV caches shard heads on ``model`` when the head count divides the axis, else
the *sequence* dim (GSPMD then computes decode softmax as partial reductions +
tiny all-reduces — flash-decode semantics). Recurrent states shard d_inner.

Tiny-model exception: xlstm-125m blocks are replicated over ``model`` (its
heads/dims don't fill a 16-wide TP axis); it runs DP-wide instead.

ZeRO: optimizer moments/master weights additionally shard their largest
still-replicated dim over the DP axes (``zero_shard``).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import dp_axes, dp_size

# leaf-name patterns -> which dim (from the end, ignoring the leading stack
# dim) goes on the model axis. "col" = last dim, "row" = second-to-last.
_COL = re.compile(
    r"(wq'\]|wk'\]|wv'\]|wi_gate'\]|wi_up'\]|in_proj'\]|up_proj'\]|"
    r"dt_proj'\]|wq_b'\]|w_gates'\]|conv_w'\]|conv_b'\]|dt_bias'\]|D'\])")
_ROW = re.compile(r"(wo'\]|out_proj'\]|down_proj'\]|x_proj'\]|A_log'\])")
_EXPERT = re.compile(r"(w_gate'\]|w_up'\]|w_down'\])")
_REPL = re.compile(
    r"(ln'\]|norm'\]|gn_scale'\]|gate_bias'\]|router|bias|embed'\]|"
    r"wq_a'\]|wkv_a'\]|wkv_b'\]|q_ln'\]|kv_ln'\]|r_gates'\]|proj'\])")


def _spec_for_leaf(path: str, shape: Tuple[int, ...], cfg: ModelConfig,
                   tp: str) -> P:
    nd = len(shape)
    if path.endswith("['embed']"):
        return P(tp, None)
    if path.endswith("['lm_head']"):
        return P(None, tp)
    if cfg.name == "xlstm-125m":
        return P(*([None] * nd))          # DP-only tiny model
    if _EXPERT.search(path):
        # [*, E, d, f] / [*, E, f, d]: experts on model (EP)
        return P(*([None] * (nd - 3) + [tp, None, None]))
    if path.endswith("['wkv_b']"):
        # [*, kvr, H*(dn+dv)]: heads (last dim) on model
        return P(*([None] * (nd - 1) + [tp]))
    if _REPL.search(path):
        return P(*([None] * nd))
    if _ROW.search(path):
        if path.endswith("['A_log']") or path.endswith("['x_proj']"):
            # [*, d_inner, n]/[*, d_inner, dtr+2n]: d_inner on model
            return P(*([None] * (nd - 2) + [tp, None]))
        return P(*([None] * (nd - 2) + [tp, None]))
    if _COL.search(path):
        return P(*([None] * (nd - 1) + [tp]))
    return P(*([None] * nd))


def param_specs(cfg: ModelConfig, params_shape: Any, mesh: Mesh,
                fsdp: bool = False) -> Any:
    """``fsdp=True`` additionally shards each parameter's largest
    still-replicated dim over the DP axes (ZeRO-3): GSPMD all-gathers weights
    at use — inside the layer scan that is per-layer gathering, trading
    collective bytes for the 1/dp weight-memory cut that lets 398B/671B
    models fit 16GB chips (see EXPERIMENTS.md §Perf)."""
    tp = "model"

    def one(path, leaf):
        p = jax.tree_util.keystr(path)
        spec = _spec_for_leaf(p, leaf.shape, cfg, tp)
        spec = _validated(spec, leaf.shape, mesh)
        if fsdp:
            spec = zero_shard(spec, leaf.shape, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(one, params_shape)


def _validated(spec: P, shape, mesh: Mesh) -> P:
    """Drop shardings that do not divide evenly (avoid padded-shard blowup)."""
    parts = []
    for i, s in enumerate(spec):
        if s is None:
            parts.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        parts.append(s if shape[i] % size == 0 else None)
    return P(*parts)


def zero_shard(spec: P, shape, mesh: Mesh) -> P:
    """ZeRO-1: shard the largest still-replicated dim over the DP axes."""
    dp = dp_axes(mesh)
    n = dp_size(mesh)
    # already DP-sharded (e.g. FSDP params): nothing to add
    for entry in spec:
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            if a in dp:
                return spec
    best, best_size = None, 0
    for i in range(len(shape)):
        cur = spec[i] if i < len(spec) else None
        if cur is None and shape[i] % n == 0 and shape[i] > best_size:
            best, best_size = i, shape[i]
    if best is None:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    parts[best] = dp if len(dp) > 1 else dp[0]
    return P(*parts)


def opt_state_specs(cfg: ModelConfig, opt_shape: Any, pspecs: Any, mesh: Mesh) -> Any:
    """Moments/master mirror the params + ZeRO sharding; step is replicated."""
    def one(path, leaf):
        p = jax.tree_util.keystr(path)
        if p.endswith("['step']"):
            return P()
        # strip the leading "['m']"/"['v']"/"['master']" to find the param
        sub = p.split("]", 1)[1]
        pspec = _lookup(pspecs, sub)
        if pspec is None:
            pspec = P(*([None] * len(leaf.shape)))
        return zero_shard(pspec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, opt_shape)


def _lookup(tree: Any, keystr_path: str) -> Optional[P]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        if jax.tree_util.keystr(path) == keystr_path:
            return leaf
    return None


def cache_specs(cfg: ModelConfig, cache_shape: Any, mesh: Mesh) -> Any:
    tp = "model"
    dp = dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    heads_fit = cfg.num_kv_heads % mesh.shape[tp] == 0

    def one(path, leaf):
        p = jax.tree_util.keystr(path)
        nd = len(leaf.shape)
        B = leaf.shape[1] if nd >= 2 else 1
        bspec = dpa if B % dp_size(mesh) == 0 else None
        if cfg.name == "xlstm-125m":
            return _validated(P(*([None, bspec] + [None] * (nd - 2))), leaf.shape, mesh)
        if re.search(r"\['(k|v|cross_k|cross_v)'\]", p):
            # [R, B, S, Hkv, Dh]
            if heads_fit:
                return _validated(P(None, bspec, None, tp, None), leaf.shape, mesh)
            return _validated(P(None, bspec, tp, None, None), leaf.shape, mesh)
        if re.search(r"\['(ckv|kr)'\]", p):
            # [R, B, S, latent] — shard sequence
            return _validated(P(None, bspec, tp, None), leaf.shape, mesh)
        if "['mamba']" in p:
            # conv [R, B, K-1, di] / ssm [R, B, di, n]
            if p.endswith("['conv']"):
                return _validated(P(None, bspec, None, tp), leaf.shape, mesh)
            return _validated(P(None, bspec, tp, None), leaf.shape, mesh)
        if "['mlstm']" in p or "['slstm']" in p:
            return _validated(P(*([None, bspec] + [None] * (nd - 2))), leaf.shape, mesh)
        return _validated(P(*([None] * nd)), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def paged_cache_specs(cfg: ModelConfig, cache_shape: Any, mesh: Mesh,
                      axis: str = "model") -> Any:
    """PartitionSpecs for the *serving* paged KV pools (``init_paged_cache``
    leaves, fused head-interleaved ``[reps, Hkv, num_pages, 2, page_size,
    Dh]`` — K at interleave 0, V at 1).

    Mirrors :func:`cache_specs`' head rule: pages shard their KV-head dim on
    ``axis`` when the head count divides it; otherwise the pools stay
    replicated and the attention ops sequence-shard the computation instead
    (partial-softmax combine — see ``kernels/paged_attention/ops.py``).
    Block tables, write slots and token-id outputs are replicated host-side
    state either way. A mesh without ``axis`` (e.g. DP-only) replicates the
    pools, matching the ops dispatch's size-1 fallback. The shared
    ``head_shards`` rule keeps placement, ops dispatch and reporting in
    lockstep."""
    from repro.kernels.shard_utils import head_shards
    heads_fit = head_shards(cfg.num_kv_heads, mesh, axis) > 1

    def one(path, leaf):
        nd = len(leaf.shape)
        if heads_fit and nd >= 2:
            return _validated(P(None, axis, *([None] * (nd - 2))),
                              leaf.shape, mesh)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> P:
    dp = dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    return P(dpa, *([None] * extra_dims))


def shardings_of(tree_shape: Any, spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda leaf, spec: jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec)),
        tree_shape, spec_tree)
