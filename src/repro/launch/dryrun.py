import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
# init). The 512 host devices exist only for this dry-run process.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

For each cell this proves, without hardware:
  * the per-arch sharding rules are coherent (GSPMD partitions the program),
  * the per-device memory footprint (``compiled.memory_analysis()``),
  * the FLOP/byte/collective volumes for the roofline table
    (``cost_analysis()`` + HLO collective parsing, scans unrolled on the
    roofline pass so loop bodies are fully counted).

Usage:
    python -m repro.launch.dryrun [--arch A] [--shape S] [--multi-pod|--both]
        [--roofline] [--out results.csv]
"""
import argparse
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis import roofline as rl
from repro.configs import ARCHS, cell_skip_reason, get_config
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, make_rctx


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             roofline: bool = False, verbose: bool = True,
             fsdp: bool = False):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    skip = cell_skip_reason(arch, SHAPES[shape_name])
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        cell = build_cell(arch, shape_name, mesh, fsdp=fsdp)
        if roofline:
            # re-lower with scans unrolled for exact cost accounting
            import dataclasses as _dc
            from repro.launch import steps as _steps
            from repro.models import model as _model
            orig = _steps.make_rctx

            def unrolled_rctx(cfg, m, **kw):
                r = orig(cfg, m, **kw)
                return _dc.replace(r, unroll_layers=True)

            _steps.make_rctx = unrolled_rctx
            try:
                cell = build_cell(arch, shape_name, mesh, fsdp=fsdp)
            finally:
                _steps.make_rctx = orig
        lowered = jax.jit(cell.fn, donate_argnums=cell.donate).lower(*cell.inputs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else (ca or {})
    mem_per_dev = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                   - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
    from repro.launch.mesh import dp_size as _dp_size
    analytic = rl.analytic_memory_bytes(cell.inputs, cell.cfg, cell.shape,
                                        _dp_size(mesh), accum=4)
    hlo = compiled.as_text()
    coll = rl.collective_bytes(hlo)
    report = rl.analyze(
        arch, shape_name, mesh_name,
        cost=ca, hlo_text=hlo, num_devices=mesh.size,
        model_flops=rl.model_flops_estimate(cell.cfg, cell.shape),
        memory_bytes_per_device=mem_per_dev,
    )
    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "mem_per_dev_gb": round(mem_per_dev / 1e9, 3),
        "temp_gb": round(ma.temp_size_in_bytes / 1e9, 3),
        "args_gb": round(ma.argument_size_in_bytes / 1e9, 3),
        "flops_per_dev": float(ca.get("flops", 0.0)),
        "bytes_per_dev": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes_per_dev": coll.total_bytes,
        "collective_ops": coll.num_ops,
        "roofline": report,
        "analytic_gb": round(analytic["total_bytes"] / 1e9, 3),
        # HBM fit judged on the analytic accounting: the CPU backend skips
        # the TPU rematerialization/scheduling passes, so its temp arena
        # overestimates peak (see analysis/roofline.analytic_memory_bytes).
        "fits_hbm": analytic["total_bytes"] <= 16e9,
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] OK "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"mem/dev={out['mem_per_dev_gb']:.2f}GB "
              f"analytic={out['analytic_gb']:.2f}GB "
              f"(fits16GB={out['fits_hbm']}) "
              f"flops/dev={out['flops_per_dev']:.3e} "
              f"coll={coll.total_bytes:.3e}B/{coll.num_ops}ops "
              f"bottleneck={report.bottleneck}", flush=True)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true", help="run 16x16 and 2x16x16")
    ap.add_argument("--roofline", action="store_true",
                    help="unroll layer scans for exact cost accounting")
    ap.add_argument("--fsdp", action="store_true",
                    help="ZeRO-3 parameter sharding over the DP axes")
    ap.add_argument("--out", default=None, help="append CSV rows here")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both else [args.multi_pod]

    failures = 0
    rows = []
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    res = run_cell(arch, shape, multi_pod=multi_pod,
                                   roofline=args.roofline, fsdp=args.fsdp)
                    if res["status"] == "skip":
                        print(f"[{arch} x {shape}] SKIP: {res['reason']}",
                              flush=True)
                    else:
                        rows.append(res)
                except Exception as e:
                    failures += 1
                    print(f"[{arch} x {shape} x "
                          f"{'2x16x16' if multi_pod else '16x16'}] FAIL: "
                          f"{type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
    if args.out and rows:
        import os.path
        header = ("arch,shape,mesh,flops_per_dev,bytes_per_dev,coll_bytes_per_dev,"
                  "t_compute_ms,t_memory_ms,t_collective_ms,bottleneck,"
                  "useful_ratio,peak_fraction,mem_per_dev_gb\n")
        new = not os.path.exists(args.out)
        with open(args.out, "a") as f:
            if new:
                f.write(header)
            for r in rows:
                f.write(r["roofline"].row() + "\n")
    print(f"dryrun: {len(rows)} ok, {failures} failed", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
