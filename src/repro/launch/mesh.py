"""Production mesh construction.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
device query, and tests/benches must keep seeing 1 device.

Two mesh families live here:

* :func:`make_production_mesh` — the training/dry-run launch mesh (pod x
  data x model).
* :func:`make_serving_mesh` — the sharded serving executor's mesh. Every
  serving entrypoint (``launch/serve.py``, ``examples/serve_streaming.py``,
  ``benchmarks/bench_goodput.py``) resolves it through the same
  ``--mesh``-flag / ``REPRO_FORCE_MESH``-env helper instead of
  re-implementing the parsing.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax

_AXIS_NAMES = ("pod", "data", "model")


def parse_mesh_spec(spec: str) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """``"2x4"`` -> ((2, 4), ("data", "model")); 1-3 ``x``-separated dims,
    named right-aligned against (pod, data, model)."""
    try:
        dims = tuple(int(x) for x in spec.split("x"))
    except ValueError:
        raise ValueError(f"bad mesh spec {spec!r} (want e.g. '2x4')")
    if not 1 <= len(dims) <= 3 or any(d < 1 for d in dims):
        raise ValueError(f"bad mesh spec {spec!r} (want 1-3 positive dims)")
    return dims, _AXIS_NAMES[-len(dims):]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``.

    The ``pod`` axis carries only data parallelism + ZeRO sharding (gradient
    reduce-scatter / all-gather), so the only pod-crossing traffic is
    DCN-friendly; ``model`` is the intra-pod tensor/expert-parallel axis.

    ``REPRO_FORCE_MESH`` (e.g. "4x8" / "2x2x8") overrides the shape — used by
    tests to exercise the full launch stack on few host devices.
    """
    forced = os.environ.get("REPRO_FORCE_MESH")
    if forced:
        dims, axes = parse_mesh_spec(forced)
        return jax.make_mesh(dims, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def serving_mesh_spec(cli_value: Optional[str] = None) -> Optional[str]:
    """Uniform mesh-override resolution shared by every serving entrypoint:
    an explicit ``--mesh`` value wins, else ``REPRO_FORCE_MESH``, else None
    (single-device engine)."""
    return cli_value or os.environ.get("REPRO_FORCE_MESH") or None


def make_serving_mesh(spec: Optional[str] = None):
    """Mesh for the sharded paged serving executor, or ``None`` for the
    single-device engine (the default — and the bit-identity baseline).

    ``spec`` like ``"2x4"`` (data x model): ``model`` is the KV/attention
    shard axis, any ``data``/``pod`` axes are replicated (the engine's host
    state — block tables, token ids — is replicated anyway, so extra axes
    only prove mesh-shape flexibility on fake host devices). A spec of total
    size 1 still builds a real mesh: it exercises the whole sharded code
    path on one device, bit-identical by construction.
    """
    spec = serving_mesh_spec(spec)
    if not spec:
        return None
    dims, axes = parse_mesh_spec(spec)
    return jax.make_mesh(dims, axes)


def add_mesh_argument(ap) -> None:
    """Attach the shared ``--mesh`` flag (serving entrypoints)."""
    ap.add_argument("--mesh", default=None,
                    help="serving mesh shape, e.g. 2x4 (data x model); "
                         "defaults to $REPRO_FORCE_MESH, else single-device")


def dp_axes(mesh) -> tuple:
    """The data-parallel axis names of a mesh (('pod','data') or ('data',))."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
