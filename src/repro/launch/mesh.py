"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
device query, and tests/benches must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``.

    The ``pod`` axis carries only data parallelism + ZeRO sharding (gradient
    reduce-scatter / all-gather), so the only pod-crossing traffic is
    DCN-friendly; ``model`` is the intra-pod tensor/expert-parallel axis.

    ``REPRO_FORCE_MESH`` (e.g. "4x8" / "2x2x8") overrides the shape — used by
    tests to exercise the full launch stack on few host devices.
    """
    import os
    forced = os.environ.get("REPRO_FORCE_MESH")
    if forced:
        dims = tuple(int(x) for x in forced.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        return jax.make_mesh(dims, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """The data-parallel axis names of a mesh (('pod','data') or ('data',))."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
