"""Distributed training launcher.

Builds the production mesh, shards params/optimizer with the per-arch rules
(+ ZeRO over the DP axes), and runs the train loop with checkpoint/restart
supervision. On this CPU container it is exercised with reduced configs and
a small forced mesh (see tests); the flags mirror a real cluster launch.

    python -m repro.launch.train --arch llama3.2-3b --steps 100 \
        --global-batch 16 --seq 256 --smoke --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import sharding as shd
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.launch.steps import make_rctx
from repro.models.model import init_params, loss_fn
from repro.runtime.fault_tolerance import TrainingSupervisor
from repro.train.checkpoint import latest_step, restore
from repro.train.data import DataConfig, PackedSyntheticData
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.train_step import TrainConfig, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-device", action="store_true",
                    help="no mesh (CPU dev loop)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = None if args.single_device else make_production_mesh(multi_pod=args.multi_pod)
    rctx = make_rctx(cfg, mesh, train=True, seq_len=args.seq)

    params = init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainConfig(optimizer=AdamWConfig(total_steps=args.steps),
                       compress_grads=args.compress_grads)
    from repro.train.train_step import init_train_state
    tstate = init_train_state(cfg, params, tcfg)
    step_fn = make_train_step(cfg, rctx, tcfg)

    if mesh is not None:
        pspecs = shd.param_specs(cfg, jax.eval_shape(lambda: params), mesh)
        params = jax.device_put(params, jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), pspecs))
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    data = PackedSyntheticData(DataConfig(cfg.vocab_size, args.seq,
                                          args.global_batch, seed=0))
    state = {"params": params, "train": tstate}

    def one_step(st, i):
        batch = {"tokens": jnp.asarray(data.batch(i))}
        p, t, m = step_fn(st["params"], st["train"], batch)
        if i % 10 == 0:
            print(f"step {i} loss={float(m['loss']):.4f}", flush=True)
        return {"params": p, "train": t}

    t0 = time.time()
    if args.ckpt_dir:
        sup = TrainingSupervisor(args.ckpt_dir, save_every=args.save_every)
        start = latest_step(args.ckpt_dir) or 0
        if start:
            state = restore(args.ckpt_dir, start, state)
            print(f"resumed from step {start}")
        state, end, restarts = sup.run(one_step, state, start, args.steps)
        print(f"finished at step {end} ({restarts} restarts)")
    else:
        for i in range(args.steps):
            state = one_step(state, i)
    print(f"wall: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
