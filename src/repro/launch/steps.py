"""Per-(arch x shape) step builders for the dry-run and the real launchers.

``build_cell`` returns the jittable step function plus fully-sharded
``jax.ShapeDtypeStruct`` stand-ins for every input (weak-type-correct,
shardable, no device allocation) and the donation indices:

* ``train_4k``   -> train_step(params, opt_state, batch) (loss + AdamW update)
* ``prefill_32k``-> prefill_step(params, tokens/embeds, cache)
* ``decode_32k`` / ``long_500k`` -> serve_step(params, cache, tokens, pos)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import cell_skip_reason, get_config
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec
from repro.launch import sharding as shd
from repro.launch.mesh import dp_axes, dp_size
from repro.models import moe as moe_mod
from repro.models.model import (RunCtx, decode_step, init_cache, init_params,
                                loss_fn, prefill)
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    fn: Callable
    inputs: Tuple[Any, ...]          # ShapeDtypeStructs with shardings
    donate: Tuple[int, ...]
    cfg: ModelConfig
    reps_for_roofline: int           # total scanned layer reps (see analysis)


def make_rctx(cfg: ModelConfig, mesh: Optional[Mesh], *, train: bool,
              seq_len: int) -> RunCtx:
    moe_ctx = moe_mod.MoEContext(
        impl="ep" if (mesh is not None and cfg.num_experts) else "dense",
        mesh=mesh,
        dp_axes=dp_axes(mesh) if mesh is not None else (),
        tp_axis="model",
    )
    block = 1024 if seq_len >= 32768 else 512
    return RunCtx(moe=moe_ctx, remat="full" if train else "none",
                  block_q=block, block_k=block,
                  mlstm_block=min(1024, max(seq_len, 1)),
                  loss_vocab_blocks=16)


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def _enc_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    if not cfg.enc_dec:
        return 0
    if shape.kind == "train":
        return shape.seq_len // 2
    return shape.seq_len


def _dec_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    if cfg.enc_dec and shape.kind == "train":
        return shape.seq_len // 2
    return shape.seq_len


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               check_skip: bool = True, fsdp: bool = False,
               cfg_override: Optional[ModelConfig] = None,
               wide_dp: bool = False) -> Optional[Cell]:
    """``wide_dp``: for models whose blocks are replicated over ``model``
    (xlstm-125m), shard the batch over data AND model axes so every chip does
    useful work (hillclimb H1 in EXPERIMENTS.md §Perf)."""
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    if check_skip and cell_skip_reason(arch, shape):
        return None
    train = shape.kind == "train"
    rctx = make_rctx(cfg, mesh, train=train, seq_len=shape.seq_len)

    params_shape = _abstract(partial(init_params, cfg), jax.random.PRNGKey(0))
    pspecs = shd.param_specs(cfg, params_shape, mesh, fsdp=fsdp)
    params_in = shd.shardings_of(params_shape, pspecs, mesh)
    dpa = dp_axes(mesh)
    dspec = dpa if len(dpa) > 1 else dpa[0]
    n_dp = dp_size(mesh)

    from repro.models.model import build_stacks
    reps = sum(r for _, r in build_stacks(cfg))

    def sds(shape_, dtype, spec):
        return jax.ShapeDtypeStruct(shape_, dtype,
                                    sharding=NamedSharding(mesh, spec))

    B = shape.global_batch
    bspec = dspec if B % n_dp == 0 else None
    if wide_dp:
        wide_axes = dpa + ("model",)
        wide_n = n_dp * mesh.shape["model"]
        if B % wide_n == 0:
            bspec = wide_axes

    # ---- modality-frontend stubs (input_specs provides embeddings) ---------
    def frontend_inputs(batch_size: int, for_train: bool):
        extras = {}
        if cfg.num_patch_tokens:
            extras["extra_embeds"] = sds(
                (batch_size, cfg.num_patch_tokens, cfg.d_model), cfg.dtype,
                P(bspec, None, None))
        if cfg.enc_dec:
            extras["enc_embeds"] = sds(
                (batch_size, _enc_len(cfg, shape), cfg.d_model), cfg.dtype,
                P(bspec, None, None))
        return extras

    if train:
        opt_cfg = AdamWConfig()
        # Microbatching: 4 gradient-accumulation steps bound activation
        # memory (saved layer inputs scale with the microbatch, not the
        # global batch) — standard posture at 256+ chips.
        from repro.train.train_step import TrainConfig, make_train_step
        accum = 4 if B // n_dp >= 4 else 1
        if wide_dp and bspec is not None and "model" in (bspec if isinstance(bspec, tuple) else (bspec,)):
            # fully-sharded batch: microbatch slicing would force a re-gather
            # (and per-chip activations are already 1/256th) — no accum.
            accum = 1
        tcfg = TrainConfig(optimizer=opt_cfg, grad_accum=accum)
        inner_step = make_train_step(cfg, rctx, tcfg)

        def train_step(params, opt_state, batch):
            new_params, new_state, metrics = inner_step(
                params, {"opt": opt_state}, batch)
            return new_params, new_state["opt"], metrics

        opt_shape = _abstract(adamw_init, params_shape)
        ospecs = shd.opt_state_specs(cfg, opt_shape, pspecs, mesh)
        opt_in = shd.shardings_of(opt_shape, ospecs, mesh)
        seq = _dec_len(cfg, shape)
        batch_in = {"tokens": sds((B, seq), jnp.int32, P(bspec, None))}
        batch_in.update(frontend_inputs(B, True))
        return Cell(arch, shape, train_step, (params_in, opt_in, batch_in),
                    donate=(0, 1), cfg=cfg, reps_for_roofline=reps)

    enc_len = _enc_len(cfg, shape)
    if shape.kind == "prefill":
        seq = shape.seq_len

        def prefill_step(params, tokens, cache, extras):
            return prefill(cfg, params, tokens, cache, rctx=rctx, **extras)

        dec_prompt = 1 if cfg.enc_dec else seq
        cache_shape = _abstract(
            partial(init_cache, cfg, B, max(dec_prompt, 1), enc_len=enc_len))
        cspecs = shd.cache_specs(cfg, cache_shape, mesh)
        cache_in = shd.shardings_of(cache_shape, cspecs, mesh)
        tokens_in = sds((B, dec_prompt), jnp.int32, P(bspec, None))
        extras = frontend_inputs(B, False)
        return Cell(arch, shape, prefill_step,
                    (params_in, tokens_in, cache_in, extras),
                    donate=(2,), cfg=cfg, reps_for_roofline=reps)

    # decode shapes: one new token against a cache of seq_len
    seq = shape.seq_len

    def serve_step(params, cache, tokens, pos):
        return decode_step(cfg, params, tokens, cache, pos, rctx=rctx)

    # room for the new token, rounded so the seq dim stays shardable
    max_len = (seq + 8 + 255) // 256 * 256
    cache_shape = _abstract(
        partial(init_cache, cfg, B, max_len, enc_len=enc_len))
    cspecs = shd.cache_specs(cfg, cache_shape, mesh)
    cache_in = shd.shardings_of(cache_shape, cspecs, mesh)
    tokens_in = sds((B, 1), jnp.int32, P(bspec, None))
    pos_in = jax.ShapeDtypeStruct((), jnp.int32)
    return Cell(arch, shape, serve_step,
                (params_in, cache_in, tokens_in, pos_in),
                donate=(1,), cfg=cfg, reps_for_roofline=reps)
