"""Copy-on-write radix prefix cache: allocator refcount/eviction semantics
and engine-level reuse with bit-identical greedy tokens.

The hard invariant under test everywhere: enabling the cache changes how
much prefill work runs, never what it computes — greedy token streams are
bitwise equal with the cache on or off, through sharing, eviction, partial
reclaim and multi-turn reuse.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SlidingServeScheduler
from repro.serving.block_allocator import BlockAllocator
from repro.serving.engine import EngineCore
from repro.serving.request import ReqState, Request
from repro.serving.server import InferenceServer
from repro.serving.workloads import (make_shared_prefix_workload,
                                     multiturn_followup)


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama3.2-3b").smoke()


def _ids(n, seed=0):
    return (np.random.default_rng(seed).integers(1, 1000, n)).astype(np.int32)


# ---------------------------------------------------------------------------
# allocator layer: match / commit / refcount / reclaim
# ---------------------------------------------------------------------------
def test_match_commit_and_refcounted_sharing():
    a = BlockAllocator(capacity_tokens=512, block_size=16)   # 32 pages
    ids = _ids(100)
    assert a.admit(1, 80, token_ids=ids, match_limit=79)
    assert a.cached_tokens(1) == 0           # cold cache
    a.commit(1, ids, 80)                     # freeze 5 full pages
    assert a.committed_count(1) == 5
    # an identical prompt reuses the frozen chain instead of fresh pages
    free_before = len(a._free_ids)
    assert a.admit(2, 96, token_ids=ids, match_limit=95)
    assert a.cached_tokens(2) == 80
    assert a.page_table(2)[:5] == a.page_table(1)[:5]   # physically shared
    assert free_before - len(a._free_ids) == 1          # only the tail page
    a.check_invariants()
    # divergent content does not match
    other = ids.copy()
    other[3] += 1
    assert a.admit(3, 64, token_ids=other, match_limit=63)
    assert a.cached_tokens(3) == 0
    a.check_invariants()


def test_free_decrefs_and_shared_pages_survive_owner_eviction():
    """Evict-and-recompute of one owner must never touch a shared page: the
    other owner keeps reading it, and only refcount-0 pages become
    reclaimable."""
    a = BlockAllocator(capacity_tokens=512, block_size=16)
    ids = _ids(64, seed=1)
    assert a.admit(1, 64, token_ids=ids, match_limit=63)
    a.commit(1, ids, 64)                     # 4 pages (63//16 = 3 matched cap
                                             # applies to *matching*, not commit)
    assert a.admit(2, 64, token_ids=ids, match_limit=63)
    shared = a.page_table(2)[:3]
    assert shared == a.page_table(1)[:3]
    a.evict(1)                               # tier-2 relegation of owner 1
    assert a.evictions == 1 and 1 not in a.owners
    # shared pages still live (owner 2 holds refs), 1's private tail cached/freed
    assert all(a._nodes[p].refs == 1 for p in shared)
    assert 2 in a.owners and a.page_table(2)[:3] == shared
    a.check_invariants()
    a.free(2)
    # now the whole chain is refcount-0: reclaimable, still matchable
    assert a.cached_blocks >= 3
    _, ml = a.match_prefix(ids, max_tokens=63)
    assert ml == 48
    a.check_invariants()


def test_reclaim_invalidates_hash_entries_leaves_first():
    a = BlockAllocator(capacity_tokens=128, block_size=16)   # 8 pages
    ids = _ids(64, seed=2)
    assert a.admit(1, 64, token_ids=ids)
    a.commit(1, ids, 64)                     # 4 committed pages
    a.free(1)
    assert a.cached_blocks == 4 and a.free_blocks == a.num_blocks
    # allocating 6 pages reclaims 2 cached pages — the *deepest* (leaf)
    # entries go first, so the surviving prefix stays matchable
    assert a.admit(2, 96)
    assert a.cache_reclaimed == 2
    _, ml = a.match_prefix(ids)
    assert ml == 32                          # chain shortened from the tail
    # the reclaimed keys are really gone from the index
    assert len(a._index) == 2 and len(a._nodes) == 2
    a.check_invariants()
    a.free(2)
    a.check_invariants()


def test_readmission_rematches_after_partial_reclaim():
    a = BlockAllocator(capacity_tokens=256, block_size=16)
    ids = _ids(96, seed=3)
    assert a.admit(1, 96, token_ids=ids, match_limit=95)
    a.commit(1, ids, 96)
    a.free(1)
    # partial reclaim: 10 free + 6 cached; taking 12 reclaims 2 leaves
    assert a.admit(9, 192)
    assert a.cache_reclaimed == 2
    a.free(9)
    # the same request re-admits and matches exactly the surviving prefix
    assert a.admit(1, 96, token_ids=ids, match_limit=95)
    assert a.cached_tokens(1) == 64
    a.check_invariants()
    # and its commit pointer continues past the re-matched pages
    a.commit(1, ids, 96)
    assert a.committed_count(1) == 6
    a.check_invariants()


def test_counting_api_unchanged_without_token_ids():
    """The analytic simulator's path: no ids, no matches, exact legacy
    accounting (free_blocks == free + cached still holds trivially)."""
    a = BlockAllocator(capacity_tokens=160, block_size=16)
    assert a.can_admit(100, 32)
    assert not a.can_admit(200)
    assert a.admit(1, 128)
    assert not a.admit(2, 64)
    a.free(1)
    assert a.admit(2, 64)
    assert a.cached_tokens(2) == 0
    a.free(2)
    assert a.free_blocks == a.num_blocks == 10
    a.check_invariants()


# ---------------------------------------------------------------------------
# engine layer
# ---------------------------------------------------------------------------
def _engine(cfg, prefix_cache, max_budget=256, **kw):
    sched = SlidingServeScheduler(max_budget=max_budget, max_iter_time=5.0)
    kw.setdefault("kv_capacity_tokens", 4096)
    return EngineCore(cfg, sched, cache_mode="paged",
                      prefix_cache=prefix_cache, **kw)


def test_shared_prefix_parity_and_hit_rate(cfg):
    """Staggered arrivals over one system prompt: later requests must reuse
    frozen pages (hit rate > 0, less prefill computed) and the greedy token
    streams must be bitwise identical to a cache-off run."""
    reqs, prompts = make_shared_prefix_workload(
        5, cfg.vocab_size, system_len=64, unique_len=24, max_output=4,
        qps=3.0, seed=11)
    outs, stats = {}, {}
    for pc in (True, False):
        eng = _engine(cfg, pc)
        out = eng.serve([dataclasses.replace(r) for r in reqs],
                        {k: v.copy() for k, v in prompts.items()},
                        max_wall_s=600.0)
        assert not out["unfinished"]
        outs[pc], stats[pc] = out["outputs"], eng.stats
        # zero-sync + leak invariants survive the cache
        assert eng.stats.token_readbacks == eng.stats.iterations
        assert eng.alloc.free_blocks == eng.alloc.num_blocks
        eng.alloc.check_invariants()
    assert outs[True] == outs[False], "prefix cache changed greedy tokens"
    assert stats[True].cache_hit_tokens > 0
    assert stats[True].prefill_tokens < stats[False].prefill_tokens
    assert stats[False].cache_hit_tokens == 0


def test_multiturn_matches_across_generated_pages(cfg):
    """Turn 2 resubmits turn 1's transcript: the match must extend past the
    prompt into pages frozen during *decode*, and outputs must equal the
    cache-off run."""
    results = {}
    for pc in (True, False):
        server = InferenceServer(_engine(cfg, pc))
        rng = np.random.default_rng(5)
        p1 = rng.integers(1, cfg.vocab_size, 48).astype(np.int32)
        out1 = server.submit(p1, max_output=20).result()
        p2 = multiturn_followup(p1, out1, rng, cfg.vocab_size, turn_len=16)
        out2 = server.submit(p2, max_output=4).result()
        results[pc] = (out1, out2)
        if pc:
            # transcript = 48 prompt + 20 generated = 68 tokens -> at least
            # 4 frozen pages (64 tokens) must match, crossing the boundary
            # between prompt-committed and decode-committed pages
            assert server.core.stats.cache_hit_tokens >= 64
    assert results[True] == results[False]


def test_cancel_mid_prefill_decrefs_shared_pages(cfg):
    """Cancelling a request mid-prefill releases its refs immediately; pages
    it shared stay live for the other holder, its private pages return."""
    server = InferenceServer(_engine(cfg, True, max_budget=64))
    core = server.core
    rng = np.random.default_rng(3)
    shared = rng.integers(1, cfg.vocab_size, 64).astype(np.int32)
    # request A prefills + finishes: its prefix pages are frozen
    server.submit(shared, max_output=2).result()
    # B and C share A's prefix; B gets a long private tail
    pb = np.concatenate([shared, rng.integers(1, cfg.vocab_size, 200).astype(np.int32)])
    pc_ = np.concatenate([shared, rng.integers(1, cfg.vocab_size, 16).astype(np.int32)])
    hb = server.submit(pb, max_output=4)
    hc = server.submit(pc_, max_output=4)
    rb = hb.request
    for _ in range(10_000):
        server.step()
        if rb.state == ReqState.PREFILLING and rb.prefilled < rb.prompt_len:
            break
    assert rb.prefilled < rb.prompt_len, "never caught B mid-prefill"
    assert core.alloc.cached_tokens(rb.rid) >= 64
    shared_pids = core.alloc.page_table(rb.rid)[:4]
    free_before = core.alloc.free_blocks
    blocks_held = core.alloc.owners[rb.rid].blocks
    hb.cancel()
    assert rb.rid not in core.alloc.owners
    # every page B held came back (shared ones as live-for-C or cached,
    # private ones as free); C still reads the shared chain
    assert core.alloc.free_blocks >= free_before + blocks_held - 4
    core.alloc.check_invariants()
    out_c = hc.result()
    assert len(out_c) == 4
    # parity: C's stream equals a cache-off replay of the same prompt
    ref = InferenceServer(_engine(cfg, False))
    assert ref.submit(pc_, max_output=4).result() == out_c
    assert all(p in core.alloc._nodes or p in core.alloc._free_ids
               or any(p in core.alloc.owners[r].page_ids
                      for r in core.alloc.owners)
               for p in shared_pids)


def test_eviction_recompute_with_warm_cache_parity(cfg):
    """Contended pool + shared prefixes: evict-and-recompute interacts with
    frozen pages (victims decref, resumed requests re-match what survives)
    and still reproduces the uncontended greedy streams exactly."""
    reqs, prompts = make_shared_prefix_workload(
        4, cfg.vocab_size, system_len=48, unique_len=16, max_output=6,
        qps=6.0, seed=13)
    ref_eng = _engine(cfg, True, kv_capacity_tokens=4096)
    ref = ref_eng.serve([dataclasses.replace(r) for r in reqs],
                        {k: v.copy() for k, v in prompts.items()},
                        max_wall_s=600.0)
    assert not ref["unfinished"] and ref_eng.stats.evictions == 0
    eng = _engine(cfg, True, kv_capacity_tokens=128,
                  decode_reserve_tokens=0)
    out = eng.serve([dataclasses.replace(r) for r in reqs],
                    {k: v.copy() for k, v in prompts.items()},
                    max_wall_s=600.0)
    assert not out["unfinished"]
    # both eviction tiers really fired: cached pages reclaimed (tier 1) and
    # live requests relegated (tier 2)
    assert eng.stats.evictions > 0 and eng.alloc.cache_reclaimed > 0
    assert out["outputs"] == ref["outputs"], "recompute under a warm cache diverged"
    eng.alloc.check_invariants()
    assert eng.alloc.free_blocks == eng.alloc.num_blocks


def test_prefix_cache_parity_on_mesh_of_one(cfg):
    """A real 1x1 mesh drives the sharded executor code path (jit +
    shard_map, pinned out_shardings); the prefix cache must hit and stay
    bit-identical there too — page layouts survive the mesh. (The 2x4
    forced-host parity runs in CI's prefix-cache-smoke job.)"""
    from repro.launch.mesh import make_serving_mesh

    def run(mesh, pc):
        reqs, prompts = make_shared_prefix_workload(
            4, cfg.vocab_size, system_len=64, unique_len=16, max_output=3,
            qps=4.0, seed=21)
        eng = _engine(cfg, pc, mesh=mesh)
        out = eng.serve(reqs, prompts, max_wall_s=600.0)
        assert not out["unfinished"]
        return out["outputs"], eng.stats.cache_hit_tokens

    base, _ = run(None, True)
    meshed, hits = run(make_serving_mesh("1x1"), True)
    plain, zero = run(make_serving_mesh("1x1"), False)
    assert base == meshed == plain
    assert hits > 0 and zero == 0
