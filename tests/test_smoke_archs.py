"""Per-arch smoke tests on reduced (same-family) configs.

For every assigned architecture: one forward/train step on CPU asserting
output shapes and finiteness, plus the serving-critical invariant that
``prefill(S) + decode(1)`` exactly matches ``prefill(S+1)`` (teacher forcing)
and that two-chunk chunked prefill agrees with full prefill.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model import (
    RunCtx, chunk_prefill_step, decode_step, init_cache, init_params, loss_fn, prefill,
)

RCTX = RunCtx(block_q=16, block_k=16, mlstm_block=16)
B, S = 2, 64


def _setup(arch):
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    kw = {}
    if cfg.num_patch_tokens:
        kw["extra_embeds"] = (
            jax.random.normal(key, (B, cfg.num_patch_tokens, cfg.d_model), jnp.float32) * 0.02
        )
    if cfg.enc_dec:
        kw["enc_embeds"] = (
            jax.random.normal(key, (B, 32, cfg.d_model), jnp.float32) * 0.02
        )
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    return cfg, params, tokens, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch):
    cfg, params, tokens, kw = _setup(arch)
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, {"tokens": tokens[:, :S], **kw}, RCTX)
    )(params)
    assert np.isfinite(float(loss)), f"loss={loss}"
    # loss should start near ln(vocab) for a random model
    assert float(loss) < np.log(cfg.vocab_size) + 3.0
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    cfg, params, tokens, kw = _setup(arch)
    enc_len = 32 if cfg.enc_dec else 0

    cache = init_cache(cfg, B, S + 1, enc_len=enc_len)
    ref_logits, _ = prefill(cfg, params, tokens, cache, rctx=RCTX, **kw)
    assert ref_logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(ref_logits).all())

    cache = init_cache(cfg, B, S + 1, enc_len=enc_len)
    _, cache = prefill(cfg, params, tokens[:, :S], cache, rctx=RCTX, **kw)
    dec_logits, _ = decode_step(cfg, params, tokens[:, S : S + 1], cache, S, rctx=RCTX)
    np.testing.assert_allclose(np.asarray(ref_logits), np.asarray(dec_logits),
                               atol=5e-3, rtol=1e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_chunked_prefill_consistency(arch):
    cfg, params, tokens, kw = _setup(arch)
    if cfg.enc_dec:
        pytest.skip("enc-dec prefill is encoder-driven; chunked prefill n/a")
    enc_len = 0
    cache = init_cache(cfg, B, S + 1, enc_len=enc_len)
    ref_logits, _ = prefill(cfg, params, tokens[:, :S], cache, rctx=RCTX, **kw)

    cache = init_cache(cfg, B, S + 1, enc_len=enc_len)
    h = S // 2
    _, cache = chunk_prefill_step(cfg, params, tokens[:, :h], cache, 0, rctx=RCTX, **kw)
    ck_logits, cache = chunk_prefill_step(cfg, params, tokens[:, h:S], cache, h, rctx=RCTX)
    np.testing.assert_allclose(np.asarray(ref_logits), np.asarray(ck_logits),
                               atol=5e-3, rtol=1e-3)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "xlstm-125m", "jamba-1.5-large-398b"])
def test_ragged_decode(arch):
    """Per-request lengths (continuous-batching engine path)."""
    cfg, params, tokens, kw = _setup(arch)
    cache = init_cache(cfg, B, S + 8)
    _, cache = prefill(cfg, params, tokens[:, :S], cache, rctx=RCTX, **kw)
    lengths = jnp.array([S + 1, S + 1])
    logits, cache2 = decode_step(cfg, params, tokens[:, S : S + 1], cache, S,
                                 rctx=RCTX, lengths=lengths)
    ref, _ = decode_step(cfg, params, tokens[:, S : S + 1], cache, S, rctx=RCTX)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(logits), atol=5e-3, rtol=1e-3)
