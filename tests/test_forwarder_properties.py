"""Property tests for the BatchForwarder and SlidingChunker invariants
(hypothesis-driven; pure host-side, fast)."""
import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container ships without hypothesis: random-sampling shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.forwarder import BatchForwarder
from repro.core.sliding_chunker import sliding_chunker
from repro.serving.request import ReqState, Request


class LinearPredictor:
    """Latency = overhead + a * tokens + b * context (monotone in budget)."""

    def predict(self, batch):
        if not batch:
            return 0.0
        return (1e-3 + 2e-5 * sum(c for c, _ in batch)
                + 1e-8 * sum(u for _, u in batch))


def mk_prefill(rid, prompt, prefilled=0, ttft=10.0):
    r = Request(rid=rid, arrival=0.0, prompt_len=prompt, max_output=4,
                ttft_slo=ttft, tbt_slo=0.05)
    r.prefilled = prefilled
    if prefilled:
        r.state = ReqState.PREFILLING
    return r


def mk_decode(rid, ctx):
    r = Request(rid=rid, arrival=0.0, prompt_len=ctx, max_output=64,
                ttft_slo=10.0, tbt_slo=0.05)
    r.prefilled = ctx
    r.generated = 2
    r.state = ReqState.DECODING
    r.first_token_time = 0.1
    r.token_times = [0.1, 0.15]
    return r


@settings(max_examples=80, deadline=None)
@given(st.lists(st.integers(8, 2000), min_size=0, max_size=6),
       st.integers(0, 12),
       st.integers(0, 4096))
def test_allocation_conservation(prompts, n_decode, budget):
    """Allocation never exceeds the budget, never over-serves a request, and
    decodes always get exactly one token each."""
    F = BatchForwarder(LinearPredictor(), max_budget=8192)
    P = [mk_prefill(i, p) for i, p in enumerate(prompts)]
    D = [mk_decode(100 + i, 128) for i in range(n_decode)]
    alloc = F.allocate(D, P, budget)
    total = sum(n for _, n in alloc)
    assert total <= max(budget, len(D))
    amap = {id(r): n for r, n in alloc}
    for r in D:
        assert amap.get(id(r)) == 1
    for r in P:
        got = amap.get(id(r), 0)
        assert 0 <= got <= r.remaining_prefill()


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(8, 2000), min_size=1, max_size=5),
       st.integers(1, 8))
def test_pred_next_conserves_work(prompts, n_decode):
    """Window-2 batches never contain more prefill work than remains after
    window 1 (the state-advance fix for Alg. 1's double-count, DESIGN D1)."""
    F = BatchForwarder(LinearPredictor(), max_budget=8192)
    P = [mk_prefill(i, p) for i, p in enumerate(prompts)]
    D = [mk_decode(100 + i, 128) for i in range(n_decode)]
    _, alloc1 = F.forward(D, P, 1024)
    batch2 = F._next_batch(D, P, alloc1, 10_000)
    taken1 = sum(n for r, n in alloc1 if n > 1)
    prefill2 = sum(c for c, _ in batch2 if c > 1)
    total_work = sum(p for p in prompts)
    assert taken1 + prefill2 <= total_work


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(64, 4000), min_size=1, max_size=4),
       st.floats(0.02, 0.5), st.floats(0.02, 0.5))
def test_chunker_liveness_and_budget_bounds(prompts, t_cur, t_next):
    """The chunker always schedules work when work+slack exist, and its
    predicted current-window time respects the clamp."""
    F = BatchForwarder(LinearPredictor(), max_budget=8192)
    P = [mk_prefill(i, p) for i, p in enumerate(prompts)]
    b, alloc, pred = sliding_chunker([], P, 8192, 0.0, t_cur, t_next, F)
    assert alloc, "liveness: pending work must be scheduled"
    assert pred <= t_cur + 1e-9, "clamp: current window may not exceed T_cur"
    assert b <= sum(prompts), "budget never exceeds pending work"


# ---------------------------------------------------------------------------
# class-aware within-round budget shares (work-conserving spillover)
# ---------------------------------------------------------------------------
def mk_classed(rid, prompt, slo_class):
    r = mk_prefill(rid, prompt)
    r.slo_class = slo_class
    return r


def test_class_shares_weight_interactive_over_batch():
    """With both classes hungry, the split follows the rank weights instead
    of handing the whole budget to whoever sorts first."""
    from repro.core.forwarder import DEFAULT_CLASS_SHARES
    F = BatchForwarder(LinearPredictor(), max_budget=8192,
                       class_shares=DEFAULT_CLASS_SHARES)
    # batch-class request sorts FIRST (priority order favors it), yet the
    # interactive request still receives its weighted share
    P = [mk_classed(0, 1000, "batch"), mk_classed(1, 1000, "interactive")]
    alloc = F.allocate([], P, 100)
    got = {r.rid: n for r, n in alloc}
    assert sum(got.values()) == 100            # work-conserving
    assert got[1] == 80 and got[0] == 20       # 4:1 weights


def test_class_shares_spill_over_when_a_class_runs_dry():
    """A class that cannot consume its share donates the remainder — the
    round never runs under budget because one class ran out of work."""
    from repro.core.forwarder import DEFAULT_CLASS_SHARES
    F = BatchForwarder(LinearPredictor(), max_budget=8192,
                       class_shares=DEFAULT_CLASS_SHARES)
    P = [mk_classed(0, 10, "interactive"), mk_classed(1, 1000, "batch")]
    alloc = F.allocate([], P, 100)
    got = {r.rid: n for r, n in alloc}
    assert got[0] == 10 and got[1] == 90
    assert sum(got.values()) == 100


def test_single_class_round_reduces_to_legacy_split():
    """One class present -> exactly the class-blind priority-order split
    (decodes first, then prefill in order until the budget runs out)."""
    from repro.core.forwarder import DEFAULT_CLASS_SHARES
    shared = dict(max_budget=8192)
    F_aware = BatchForwarder(LinearPredictor(), class_shares=DEFAULT_CLASS_SHARES,
                             **shared)
    F_blind = BatchForwarder(LinearPredictor(), **shared)
    P = [mk_classed(i, 300, "standard") for i in range(4)]
    D = [mk_decode(100 + i, 64) for i in range(3)]
    a1 = [(r.rid, n) for r, n in F_aware.allocate(D, P, 512)]
    a2 = [(r.rid, n) for r, n in F_blind.allocate(D, P, 512)]
    assert a1 == a2
