"""Paper-claim integration tests on the serving simulator.

These validate the qualitative claims the benchmarks quantify:
- SlidingServe >> Sarathi-EDF under load (Fig. 4/5 direction),
- predictor fidelity on live traces (Table 5 direction),
- relegation advantage under deep overload (§5.2 discussion).
"""
import numpy as np
import pytest

from repro.configs.bench_models import QWEN25_7B
from repro.core import (SarathiEDFScheduler, SingleStepGreedyScheduler,
                        SlidingServeScheduler)
from repro.core.predictor import BatchLatencyPredictor
from repro.serving.costmodel import CostModel, HardwareSpec, ModelProfile
from repro.serving.metrics import summarize
from repro.serving.simulator import ServingSimulator
from repro.serving.workloads import WorkloadSpec, make_workload

PROF = ModelProfile.from_config(QWEN25_7B)


def run(sched_cls, qps, dataset, dur=90.0, seed=3, **kw):
    cm = CostModel(PROF, HardwareSpec(chips=1), seed=7)
    wl = make_workload(WorkloadSpec(dataset, qps, dur, seed=seed), cm)
    sched = sched_cls(max_budget=4096, **kw)
    sim = ServingSimulator(sched, cm, wl, kv_capacity_tokens=512 * 1024)
    res = sim.run()
    return summarize(res.requests, res.duration), res


def test_slidingserve_beats_sarathi_under_load():
    # qps 16 saturates the cost model: Sarathi's TBT-calibrated static chunk
    # cannot trade the two windows off, SlidingServe can. (The original qps
    # 5.0 only separated the schedulers while sarathi-edf ran a miscalibrated
    # 512-token chunk — with the baseline fixed, both serve 5 qps cleanly.)
    s_sliding, _ = run(SlidingServeScheduler, 16.0, "sharegpt")
    s_sarathi, _ = run(SarathiEDFScheduler, 16.0, "sharegpt")
    assert s_sliding["violation_rate"] < 0.5 * s_sarathi["violation_rate"], (
        s_sliding["violation_rate"], s_sarathi["violation_rate"])


def test_relegation_advantage_under_deep_overload():
    """Under deep overload, SlidingServe's urgency+relegation keeps serving
    savable requests while deadline-only schedulers collapse (paper §5.2)."""
    s_sliding, _ = run(SlidingServeScheduler, 2.8, "arxiv-v1", dur=120.0)
    s_sarathi, _ = run(SarathiEDFScheduler, 2.8, "arxiv-v1", dur=120.0)
    assert s_sliding["violation_rate"] < 0.7 * s_sarathi["violation_rate"], (
        s_sliding["violation_rate"], s_sarathi["violation_rate"])


def test_scheduler_routes_both_branches():
    """The Fig. 3 closed loop must exercise both SlidingChunker and
    BatchConstructor on a bursty mixed workload."""
    _, res = run(SlidingServeScheduler, 4.5, "mixed-v1", dur=60.0)
    assert res.route_counts.get("sliding", 0) > 0
    # BC fires only under actionable TTFT risk; mixed overload produces some
    assert "construct" in res.route_counts or res.route_counts["sliding"] > 100


def test_predictor_fidelity_on_live_trace():
    cm = CostModel(PROF, HardwareSpec(chips=1), seed=7)
    wl = make_workload(WorkloadSpec("mixed-v1", 2.5, 90.0, seed=9), cm)
    sched = SlidingServeScheduler(max_budget=4096)
    samples = []
    orig = sched.observe
    def spy(batch, latency, **kw):
        samples.append((list(batch), latency, cm.latency(batch, noisy=False)))
        orig(batch, latency, **kw)
    sched.observe = spy
    ServingSimulator(sched, cm, wl, kv_capacity_tokens=512 * 1024).run()
    assert len(samples) > 300
    split = len(samples) // 2
    p = BatchLatencyPredictor()
    p.fit_offline([(b, y) for b, y, _ in samples[:split]])
    ev_clean = p.evaluate([(b, yc) for b, _, yc in samples[split:]])
    # paper Table 5: R^2 > 0.99 vs real runtimes; vs the clean (noise-free)
    # target our per-scene linear experts reach the same bar
    assert ev_clean["r2"] > 0.97, ev_clean
