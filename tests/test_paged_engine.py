"""Paged serving engine tests: slot-vs-paged output equivalence, allocator
eviction/recompute round-trip through the real engine, and the concurrency /
dispatch-count acceptance properties of the fused mixed-batch design."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SlidingServeScheduler
from repro.serving.block_allocator import BlockAllocator
from repro.serving.engine import ServingEngine
from repro.serving.request import ReqState, Request


def _mk_requests(spec):
    return [Request(rid=i, arrival=a, prompt_len=p, max_output=o,
                    ttft_slo=900.0, tbt_slo=900.0)
            for i, (a, p, o) in enumerate(spec)]


def _serve(cfg, prompts, spec, **engine_kw):
    reqs = _mk_requests(spec)
    sched = SlidingServeScheduler(max_budget=256, max_iter_time=5.0)
    eng = ServingEngine(cfg, sched, seed=0, **engine_kw)
    out = eng.serve(reqs, {k: v.copy() for k, v in prompts.items()},
                    max_wall_s=900.0)
    return eng, out


# ---------------------------------------------------------------------------
# allocator page-id layer
# ---------------------------------------------------------------------------
def test_allocator_page_ids_and_victim_policy():
    a = BlockAllocator(capacity_tokens=256, block_size=16)   # 16 pages
    assert a.admit(1, 40) and a.admit(2, 40)                 # 3 pages each
    t1, t2 = a.page_table(1), a.page_table(2)
    assert len(t1) == 3 and len(t2) == 3 and not set(t1) & set(t2)
    assert a.grow(1, 70)                                     # 5 pages
    assert a.page_table(1)[:3] == t1                         # ids are stable
    a.check_invariants()
    # victim = lowest priority (largest key), never the needy request
    assert a.pick_victim(1, priority=lambda rid: rid) == 2
    assert a.pick_victim(2, priority=lambda rid: rid) == 1
    a.evict(2)
    assert a.evictions == 1 and 2 not in a.owners
    a.free(1)
    a.check_invariants()
    assert a.free_blocks == a.num_blocks


def test_allocator_free_tokens_counts_tail_slack():
    a = BlockAllocator(capacity_tokens=160, block_size=16)   # 10 pages
    assert a.admit(7, 20)    # 2 pages, 12 tokens of tail slack
    assert a.free_tokens() == 8 * 16 + 12


# ---------------------------------------------------------------------------
# engine equivalence + acceptance properties
# ---------------------------------------------------------------------------
def test_slot_vs_paged_same_greedy_tokens():
    cfg = get_config("llama3.2-3b").smoke()
    rng = np.random.default_rng(1)
    spec = [(0.0, 24, 4), (0.0, 51, 4), (0.0, 37, 3)]
    prompts = {i: rng.integers(1, cfg.vocab_size, p).astype(np.int32)
               for i, (_, p, _) in enumerate(spec)}
    _, out_slot = _serve(cfg, prompts, spec, cache_mode="slot",
                         max_slots=4, max_len=512)
    eng, out_paged = _serve(cfg, prompts, spec, cache_mode="paged",
                            kv_capacity_tokens=2048)
    assert not out_slot["unfinished"] and not out_paged["unfinished"]
    assert out_slot["outputs"] == out_paged["outputs"]
    assert eng.stats.evictions == 0


def test_paged_concurrency_beyond_slot_ceiling():
    """The paged engine admits strictly more concurrent requests than the
    slot engine's max_slots=8 ceiling, and a scheduler round costs at most
    two fused model dispatches no matter how many requests it names."""
    cfg = get_config("llama3.2-3b").smoke()
    rng = np.random.default_rng(2)
    spec = [(0.0, int(rng.integers(16, 48)), 2) for _ in range(12)]
    prompts = {i: rng.integers(1, cfg.vocab_size, p).astype(np.int32)
               for i, (_, p, _) in enumerate(spec)}
    eng, out = _serve(cfg, prompts, spec, cache_mode="paged",
                      max_slots=8, kv_capacity_tokens=4096)
    assert not out["unfinished"]
    assert eng.stats.max_concurrency > 8
    assert eng.stats.max_round_calls <= 2
    assert eng.alloc.free_blocks == eng.alloc.num_blocks  # all KV released


def test_eviction_recompute_roundtrip():
    """Saturate a tiny paged KV so decode growth must evict; the evicted
    request recomputes (prompt + already-emitted tokens) and every request
    still produces exactly the tokens an uncontended engine produces."""
    cfg = get_config("llama3.2-3b").smoke()
    rng = np.random.default_rng(3)
    spec = [(0.0, 60, 6) for _ in range(4)]
    prompts = {i: rng.integers(1, cfg.vocab_size, 60).astype(np.int32)
               for i in range(4)}
    # reference: ample capacity, no evictions
    ref_eng, ref = _serve(cfg, prompts, spec, cache_mode="paged",
                          kv_capacity_tokens=4096)
    assert ref_eng.stats.evictions == 0 and not ref["unfinished"]
    # contended: 4 x 60-token prompts round to exactly 16 pages; the 65th
    # token of each stream needs a 5th page -> growth failure -> eviction
    eng, out = _serve(cfg, prompts, spec, cache_mode="paged",
                      kv_capacity_tokens=256, page_size=16,
                      decode_reserve_tokens=0)
    assert not out["unfinished"], \
        f"unfinished after eviction: {[r.rid for r in out['unfinished']]}"
    assert eng.stats.evictions > 0, "KV was never contended"
    assert out["outputs"] == ref["outputs"], "recompute diverged from greedy"
    for r in out["finished"]:
        assert r.generated == 6 and len(out["outputs"][r.rid]) == 6
    eng.alloc.check_invariants()
    assert eng.alloc.free_blocks == eng.alloc.num_blocks


def test_oversized_allocation_splits_across_dispatches(monkeypatch):
    """An allocation above the top chunk bucket is split across dispatches
    (never silently truncated), and the split dispatches address only the
    page-table prefix they read — regression for the table-width overflow."""
    import repro.serving.engine as E
    monkeypatch.setattr(E, "CHUNK_BUCKETS", (16, 32))
    cfg = get_config("llama3.2-3b").smoke()
    rng = np.random.default_rng(4)
    spec = [(0.0, 100, 2)]
    prompts = {0: rng.integers(1, cfg.vocab_size, 100).astype(np.int32)}
    eng, out = _serve(cfg, prompts, spec, cache_mode="paged",
                      kv_capacity_tokens=1024)
    assert not out["unfinished"]
    assert eng.stats.prefill_calls >= 4      # 100 tokens over a 32-token cap
    _, ref = _serve(cfg, prompts, spec, cache_mode="slot",
                    max_slots=2, max_len=512)
    assert out["outputs"] == ref["outputs"]


def test_single_token_readback_per_round():
    """Zero-sync hot path: paged mode performs exactly one token-id
    device->host readback per executed scheduler round, regardless of how
    many requests a round batches — and the deferred-readback pipeline emits
    the same greedy tokens as the sync-every-row legacy mode."""
    cfg = get_config("llama3.2-3b").smoke()
    rng = np.random.default_rng(5)
    spec = [(0.0, int(rng.integers(16, 48)), 3) for _ in range(8)]
    prompts = {i: rng.integers(1, cfg.vocab_size, p).astype(np.int32)
               for i, (_, p, _) in enumerate(spec)}

    calls = []
    orig = ServingEngine._readback

    def spy(self, arr):
        calls.append(np.shape(arr))
        return orig(self, arr)

    ServingEngine._readback = spy
    try:
        eng, out = _serve(cfg, prompts, spec, cache_mode="paged",
                          kv_capacity_tokens=4096)
    finally:
        ServingEngine._readback = orig
    assert not out["unfinished"]
    st = eng.stats
    # _readback is the paged path's only sync point; one call per round.
    assert len(calls) == st.token_readbacks == st.iterations, (
        len(calls), st.token_readbacks, st.iterations)
    assert st.max_concurrency > 1      # rounds really were batched
    assert st.sync_s > 0.0 and st.host_s > 0.0

    # legacy sync-every-row mode: same tokens, strictly more transfers
    eng2, out2 = _serve(cfg, prompts, spec, cache_mode="paged",
                        kv_capacity_tokens=4096, overlap=False)
    assert not out2["unfinished"]
    assert out2["outputs"] == out["outputs"]
    assert eng2.stats.token_readbacks > eng2.stats.iterations


def test_row_bucket_ladder_bounds_compiled_shapes(monkeypatch):
    """Concurrency above the top row bucket splits across dispatches instead
    of minting new compiled row shapes: every JIT'd shape uses a row count
    from ROW_BUCKETS, so compiled_shapes stays bounded no matter how many
    requests arrive."""
    import repro.serving.engine as E
    monkeypatch.setattr(E, "ROW_BUCKETS", (1, 2, 4))
    cfg = get_config("llama3.2-3b").smoke()
    rng = np.random.default_rng(6)
    spec = [(0.0, int(rng.integers(8, 24)), 3) for _ in range(10)]
    prompts = {i: rng.integers(1, cfg.vocab_size, p).astype(np.int32)
               for i, (_, p, _) in enumerate(spec)}
    eng, out = _serve(cfg, prompts, spec, cache_mode="paged",
                      kv_capacity_tokens=8192)
    assert not out["unfinished"]
    assert eng.stats.max_concurrency > 4   # really ran above the top rung
    rows_seen = {k[1] for k in eng._seen_shapes}
    assert rows_seen <= {1, 2, 4}, rows_seen
    # the ladder bounds the total shape universe:
    #   chunk shapes <= |rows| * |chunk buckets| * |table widths|, decode
    #   shapes <= |rows| * |table widths| — assert the cheap invariant that
    #   nothing outside the ladder was compiled.
    assert eng.stats.compiled_shapes == len(eng._seen_shapes)
    # outputs unaffected by the split
    _, ref = _serve(cfg, prompts, spec, cache_mode="slot",
                    max_slots=10, max_len=256)
    assert out["outputs"] == ref["outputs"]


def test_paged_rejects_recurrent_arch():
    cfg = get_config("xlstm-125m").smoke()
    sched = SlidingServeScheduler(max_budget=128)
    with pytest.raises(ValueError):
        ServingEngine(cfg, sched, cache_mode="paged")
    eng = ServingEngine(cfg, sched, cache_mode="auto", max_slots=2,
                        max_len=128)
    assert eng.cache_mode == "slot"
