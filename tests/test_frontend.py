"""Network front door tests: HTTP/SSE transport, prefix-affine router,
shared prefix directory, graceful drain, and in-flight burst sharing.

Pins the new-subsystem acceptance properties:

* SSE token streams over HTTP are **bit-identical** to the in-process API
  (greedy tokens depend only on the prompt — transport must not matter);
* cancelling over HTTP mid-stream aborts server-side and every KV page
  returns to the allocator;
* the router steers a shared-prefix stream onto the replica already holding
  the prefix (directory affinity) and spills to the least-loaded replica
  when the holder saturates;
* every replica keeps the one-readback-per-round zero-sync invariant under
  router pumping;
* a burst of requests sharing an uncommitted prefix defers the followers
  until the leader commits — the followers then prefill only their suffix
  (in-flight burst sharing), with greedy tokens unchanged;
* ``InferenceServer.close()`` drains, settles every handle, verifiably
  reclaims pages/slots, and refuses new admissions.
"""
import asyncio
import threading
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SlidingServeScheduler
from repro.frontend.client import EngineHttpClient
from repro.frontend.http_server import HttpFrontend, build_backend
from repro.frontend.prefix_directory import PrefixDirectory
from repro.frontend.router import EngineRouter, LocalReplica
from repro.serving.block_allocator import ROOT_CHAIN, page_chain_hash
from repro.serving.engine import EngineCore
from repro.serving.server import InferenceServer


def _server(cfg, **kw):
    kw.setdefault("max_budget", 256)
    budget = kw.pop("max_budget")
    kw.setdefault("kv_capacity_tokens", 2048)
    kw.setdefault("cache_mode", "paged")
    return InferenceServer.build(
        cfg, scheduler=SlidingServeScheduler(max_budget=budget,
                                             max_iter_time=5.0), **kw)


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama3.2-3b").smoke()


# ---------------------------------------------------------------------------
# PrefixDirectory: pure unit semantics (no engine)
# ---------------------------------------------------------------------------
class TestPrefixDirectory:
    def test_chain_hashes_match_allocator_fold(self):
        d = PrefixDirectory(page_size=4)
        toks = list(range(10))
        chain = d.chain_hashes(toks)
        assert len(chain) == 2                      # whole pages only
        h0 = page_chain_hash(ROOT_CHAIN, toks[:4])
        assert chain == [h0, page_chain_hash(h0, toks[4:8])]

    def test_match_requires_contiguous_chain(self):
        d = PrefixDirectory(page_size=4)
        toks = list(range(12))
        chain = d.chain_hashes(toks)
        d.on_commit(0, chain[0])
        d.on_commit(0, chain[1])
        d.on_commit(1, chain[1])    # page 2 without page 1: unreachable
        m = d.match(toks)
        assert m == {0: 8}          # replica 1 holds no usable prefix

    def test_reclaim_drops_holder(self):
        d = PrefixDirectory(page_size=4)
        toks = list(range(8))
        chain = d.chain_hashes(toks)
        for h in chain:
            d.on_commit(0, h)
        assert d.match(toks) == {0: 8}
        d.on_reclaim(0, chain[1])
        assert d.match(toks) == {0: 4}
        d.on_reclaim(0, chain[0])
        assert d.match(toks) == {}
        assert d.pages_held(0) == 0

    def test_listener_adapter_and_stats(self):
        d = PrefixDirectory(page_size=4)
        lst = d.listener_for(2)
        h = page_chain_hash(ROOT_CHAIN, [1, 2, 3, 4])
        lst.on_commit(h, 1)
        assert d.match([1, 2, 3, 4, 9]) == {2: 4}
        st = d.stats()
        assert st["commits"] == 1 and st["hit_lookups"] == 1
        lst.on_reclaim(h)
        assert d.stats()["entries"] == 0


# ---------------------------------------------------------------------------
# in-flight burst sharing (defer-shared admission)
# ---------------------------------------------------------------------------
def _burst(cfg, n=4, system_len=64, unique_len=8, seed=3):
    rng = np.random.default_rng(seed)
    system = rng.integers(1, cfg.vocab_size, system_len).astype(np.int32)
    prompts = {i: np.concatenate(
        [system, rng.integers(1, cfg.vocab_size, unique_len).astype(np.int32)])
        for i in range(n)}
    return prompts


def test_burst_sharing_defers_followers_and_saves_prefill(cfg):
    """K requests sharing an uncommitted prefix arrive in one burst: the
    followers must wait for the leader's commits instead of prefilling the
    shared pages cold — asserted as computed-prefill savings vs the
    defer-disabled engine, with identical greedy tokens."""
    prompts = _burst(cfg)
    outs, computed, deferred = {}, {}, {}
    for defer in (True, False):
        srv = _server(cfg, defer_shared=defer)
        handles = {i: srv.submit(p.copy(), max_output=3)
                   for i, p in prompts.items()}
        srv.run(max_wall_s=900.0)
        assert all(h.finished for h in handles.values())
        outs[defer] = {i: list(h.collected) for i, h in handles.items()}
        computed[defer] = srv.core.stats.prefill_tokens
        deferred[defer] = srv.core.stats.deferred_admissions
    assert outs[True] == outs[False], "defer-shared changed greedy tokens"
    assert deferred[True] > 0, "burst never deferred a follower"
    assert deferred[False] == 0
    # 3 followers x 64 shared tokens = 192 potentially shared; deferral must
    # recover at least the whole pages of the shared prefix for them
    saved = computed[False] - computed[True]
    page = 16
    assert saved >= 3 * (64 // page * page - page), \
        f"only {saved} prefill tokens saved by deferral"


def test_defer_cannot_wedge_without_leader(cfg):
    """A lone request (no leader to wait for) must admit immediately even
    with deferral on; the cap bounds pathological waits."""
    srv = _server(cfg, defer_shared=True)
    h = srv.submit(np.arange(1, 40, dtype=np.int32), max_output=3)
    assert h.result(max_wall_s=900.0)
    assert srv.core.stats.deferred_admissions == 0


# ---------------------------------------------------------------------------
# graceful shutdown / drain
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["paged", "slot"])
def test_close_drains_and_reclaims(cfg, mode):
    srv = _server(cfg, cache_mode=mode) if mode == "paged" else \
        InferenceServer.build(
            cfg, scheduler=SlidingServeScheduler(max_budget=256,
                                                 max_iter_time=5.0),
            cache_mode="slot", max_slots=4, max_len=512)
    rng = np.random.default_rng(0)
    hs = [srv.submit(rng.integers(1, cfg.vocab_size, 24).astype(np.int32),
                     max_output=3) for _ in range(3)]
    report = srv.close(drain_s=120.0)
    assert report["drained"] and report["finished"] == 3
    assert all(h.finished for h in hs)
    # close() itself asserts pages/slots reclaimed; re-check from outside
    if mode == "paged":
        assert srv.core.alloc.free_blocks == srv.core.alloc.num_blocks
    else:
        assert len(srv.core.free_slots) == srv.core.max_slots
    with pytest.raises(RuntimeError):
        srv.submit(np.arange(1, 10, dtype=np.int32))
    assert srv.close() is report            # idempotent


def test_close_aborts_stragglers_at_deadline(cfg):
    srv = _server(cfg)
    h = srv.submit(np.arange(1, 60, dtype=np.int32), max_output=512)
    report = srv.close(drain_s=0.0)         # no time to drain: abort sweep
    assert h.finished and h.aborted
    assert report["aborted"] == 1
    assert srv.core.alloc.free_blocks == srv.core.alloc.num_blocks


# ---------------------------------------------------------------------------
# router: affinity, spillover, zero-sync per replica
# ---------------------------------------------------------------------------
def test_router_affinity_lands_shared_stream_on_one_replica(cfg):
    router = EngineRouter([LocalReplica(i, _server(cfg)) for i in range(2)])
    prompts = _burst(cfg, n=4, seed=5)
    # sequential: each request finishes (and commits) before the next routes
    owners = []
    for i, p in enumerate(prompts.values()):
        h = router.submit(p.copy(), max_output=3)
        router.run(max_wall_s=900.0)
        assert h.finished
        owners.append(router.owner_of(h.rid))
    # after the first commits, every follower must land on its holder
    assert len(set(owners[1:])) == 1 and owners[1] == owners[0]
    assert router.affine_hits >= len(prompts) - 1
    assert router.directory.stats()["hit_rate"] > 0.5
    # zero-sync invariant per replica under router pumping
    for rep in router.replicas:
        st = rep.server.core.stats
        assert st.token_readbacks == st.iterations
    report = router.close()
    assert report["drained"]


def test_router_spills_when_holder_saturated(cfg):
    router = EngineRouter([LocalReplica(i, _server(cfg)) for i in range(2)],
                          spill_factor=2.0)
    prompts = _burst(cfg, n=2, seed=6)
    first = router.submit(prompts[0].copy(), max_output=3)
    router.run(max_wall_s=900.0)
    holder = router.owner_of(first.rid)
    # saturate the holder: a large queued backlog it has not started
    rng = np.random.default_rng(9)
    for _ in range(6):
        router.replicas[holder].server.submit(
            rng.integers(1, cfg.vocab_size, 120).astype(np.int32),
            max_output=64)
    # the shared-prefix follower matches the holder but must spill away
    h = router.submit(prompts[1].copy(), max_output=3)
    assert router.owner_of(h.rid) != holder
    assert router.spills == 1
    router.run(max_wall_s=900.0)
    router.close()


def test_router_round_robin_ignores_directory(cfg):
    router = EngineRouter([LocalReplica(i, _server(cfg)) for i in range(2)],
                          policy="round-robin")
    prompts = _burst(cfg, n=4, seed=7)
    for p in prompts.values():
        router.submit(p.copy(), max_output=2)
        router.run(max_wall_s=900.0)
    assert router.routed == [2, 2]
    assert router.directory.stats()["lookups"] == 0
    router.close()


def test_router_parity_with_single_engine(cfg):
    prompts = _burst(cfg, n=3, seed=8)
    single = _server(cfg)
    ref = {i: single.submit(p.copy(), max_output=4).result(900.0)
           for i, p in prompts.items()}
    router = EngineRouter([LocalReplica(i, _server(cfg)) for i in range(2)])
    got = {}
    for i, p in prompts.items():
        h = router.submit(p.copy(), max_output=4)
        router.run(max_wall_s=900.0)
        got[i] = list(h.collected)
    assert got == ref, "routing changed greedy tokens"
    router.close()


# ---------------------------------------------------------------------------
# HTTP/SSE transport (in-thread server; the subprocess path is
# examples/router_smoke.py)
# ---------------------------------------------------------------------------
@pytest.fixture()
def http_fe(cfg):
    backend = build_backend(replicas=1, kv_tokens=2048, max_budget=256)
    fe = HttpFrontend(backend, port=0, drain_s=30.0)
    th = threading.Thread(target=lambda: asyncio.run(fe.serve_forever()),
                          daemon=True)
    th.start()
    cli = EngineHttpClient(port=0, timeout=300.0)
    t_end = time.perf_counter() + 60.0
    while fe.port == 0 and time.perf_counter() < t_end:
        time.sleep(0.02)
    cli.port = fe.port
    cli.wait_ready(60.0)
    yield fe, cli, backend
    fe.request_stop()
    th.join(timeout=60.0)
    assert not th.is_alive(), "HTTP server failed to drain on stop"


def test_http_sse_parity_with_inprocess(cfg, http_fe):
    fe, cli, backend = http_fe
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
               for n in (24, 40, 33)]
    ref_srv = _server(cfg)
    ref = [ref_srv.submit(np.asarray(p, np.int32), max_output=4).result(900.0)
           for p in prompts]
    got = [cli.generate(p, max_output=4).result() for p in prompts]
    assert got == ref, "SSE stream diverged from the in-process API"


def test_http_cancel_mid_stream_reclaims_pages(cfg, http_fe):
    fe, cli, backend = http_fe
    rng = np.random.default_rng(4)
    h = cli.generate(rng.integers(1, cfg.vocab_size, 48).tolist(),
                     max_output=512)
    seen = 0
    for _ in h.tokens():
        seen += 1
        if seen == 1:
            assert h.cancel()
    assert h.aborted and seen < 512
    # the abort must have freed every page the request held; wait for the
    # pump to settle the engine then check the pool refilled
    core = backend.core
    t_end = time.perf_counter() + 60.0
    while core.has_work() and time.perf_counter() < t_end:
        time.sleep(0.02)
    held = core.alloc.num_blocks - core.alloc.free_blocks
    assert held == 0, f"{held} pages still live after HTTP cancel"
    assert core.stats.aborted == 1


@pytest.fixture()
def http_fe_spec(cfg):
    """Front door over a speculating engine whose drafter replays the known
    greedy stream — every draft is accepted, so each decode round emits a
    full multi-token burst (deterministic coverage for batched SSE frames)."""
    from repro.serving.drafter import DrafterBase

    rng = np.random.default_rng(12)
    prompt = rng.integers(1, cfg.vocab_size, 24).astype(np.int32)
    ref_srv = _server(cfg)
    ref = ref_srv.submit(prompt.copy(), max_output=8).result(900.0)

    class ReplayDrafter(DrafterBase):
        def propose(self, context, k):
            gen = len(context) - len(prompt)
            if gen < 0 or gen >= len(ref):
                return None
            out = np.asarray(ref[gen:gen + k], np.int32)
            return out if len(out) else None

    backend = build_backend(replicas=1, kv_tokens=2048, max_budget=256,
                            spec_k=4, drafter=ReplayDrafter())
    fe = HttpFrontend(backend, port=0, drain_s=30.0)
    th = threading.Thread(target=lambda: asyncio.run(fe.serve_forever()),
                          daemon=True)
    th.start()
    cli = EngineHttpClient(port=0, timeout=300.0)
    t_end = time.perf_counter() + 60.0
    while fe.port == 0 and time.perf_counter() < t_end:
        time.sleep(0.02)
    cli.port = fe.port
    cli.wait_ready(60.0)
    yield cli, backend, prompt, ref
    fe.request_stop()
    th.join(timeout=60.0)
    assert not th.is_alive(), "HTTP server failed to drain on stop"


def test_http_sse_batches_speculative_bursts(cfg, http_fe_spec):
    """A speculative round's burst arrives as ONE SSE `token` frame carrying
    `tokens: [ids]`, the stream equals the unspeculated reference, and the
    legacy single-`token` field still carries the frame's first id."""
    cli, backend, prompt, ref = http_fe_spec
    h = cli.generate(prompt.tolist(), max_output=8)
    got = h.result()
    assert got == ref, "speculative SSE stream diverged from greedy"
    frames = [d for name, d in h.events if name == "token"]
    assert frames, "no token frames seen"
    assert all("tokens" in d and d["token"] == d["tokens"][0] for d in frames)
    assert any(len(d["tokens"]) > 1 for d in frames), \
        "full-acceptance speculation never batched an SSE frame"
    # terminal frame counts every token of every burst
    fin = next(d for name, d in h.events if name == "finished")
    assert fin["n_tokens"] == len(ref)
    st = cli.stats()["engine"]
    assert st["spec_accepted"] > 0
    assert st["token_readbacks"] == st["iterations"]


def test_http_stats_and_draining_rejection(cfg, http_fe):
    fe, cli, backend = http_fe
    rng = np.random.default_rng(5)
    cli.generate(rng.integers(1, cfg.vocab_size, 24).tolist(),
                 max_output=2).result()
    st = cli.stats()
    assert st["engine"]["iterations"] > 0
    assert st["engine"]["token_readbacks"] == st["engine"]["iterations"]
    assert "cache_info" in st and "per_class" in st
    assert cli.load()["outstanding_tokens"] == 0
    assert cli.prefix_feed()["next"] > 0    # commits were exported
