"""Serving substrate tests: allocator invariants (property-based), workload
statistics vs paper Table 2, request deadline math (Eq. 1), cost model
regimes, simulator conservation laws."""
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container ships without hypothesis: random-sampling shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.bench_models import QWEN25_7B
from repro.core import SlidingServeScheduler
from repro.serving.block_allocator import BlockAllocator
from repro.serving.costmodel import CostModel, HardwareSpec, ModelProfile
from repro.serving.metrics import cumulative_violations, max_goodput, summarize
from repro.serving.request import Request
from repro.serving.simulator import ServingSimulator
from repro.serving.workloads import TABLE2, WorkloadSpec, make_workload

HW = HardwareSpec(chips=1)
PROF = ModelProfile.from_config(QWEN25_7B)


# ---------------------------------------------------------------------------
# request / SLO model
# ---------------------------------------------------------------------------
def test_token_deadlines_eq1():
    r = Request(rid=0, arrival=10.0, prompt_len=100, max_output=5,
                ttft_slo=2.0, tbt_slo=0.04)
    assert r.token_deadline(1) == 12.0
    assert r.token_deadline(4) == 12.0 + 3 * 0.04
    r.emit_token(11.0)
    assert r.first_token_time == 11.0
    r.emit_token(12.1)  # due 12.04 -> late
    v = r.violations()
    assert v["ttft_miss"] == 0 and v["tbt_misses"] == 1 and v["violated"] == 1


def test_sched_slack_recovers_after_lateness():
    r = Request(rid=0, arrival=0.0, prompt_len=10, max_output=50,
                ttft_slo=0.1, tbt_slo=0.04)
    r.emit_token(5.0)  # absurdly late first token
    assert r.decode_slack(5.0) < 0            # metric slack: violated
    assert r.sched_decode_slack(5.0) > 0      # scheduling slack: cadence


# ---------------------------------------------------------------------------
# block allocator
# ---------------------------------------------------------------------------
@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["admit", "grow", "free"]),
                          st.integers(0, 7), st.integers(0, 600)),
                max_size=60))
def test_allocator_invariants(ops):
    a = BlockAllocator(capacity_tokens=2048, block_size=16)
    live = set()
    for op, rid, tokens in ops:
        if op == "admit" and rid not in live:
            if a.admit(rid, tokens % 256):
                live.add(rid)
        elif op == "grow" and rid in live:
            a.grow(rid, tokens)
        elif op == "free" and rid in live:
            a.free(rid)
            live.discard(rid)
        a.check_invariants()
    for rid in list(live):
        a.free(rid)
    assert a.free_blocks == a.num_blocks


def test_allocator_admission_control():
    a = BlockAllocator(capacity_tokens=160, block_size=16)
    assert a.can_admit(100, 32)
    assert not a.can_admit(200)
    assert a.admit(1, 128)
    assert not a.admit(2, 64)   # only 2 blocks left
    a.free(1)
    assert a.admit(2, 64)


# ---------------------------------------------------------------------------
# workloads vs Table 2
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dataset", list(TABLE2))
def test_workload_matches_table2(dataset):
    cm = CostModel(PROF, HW, seed=0)
    wl = make_workload(WorkloadSpec(dataset, qps=20.0, duration=400, seed=11), cm)
    p = np.array([r.prompt_len for r in wl])
    o = np.array([r.max_output for r in wl])
    tgt = TABLE2[dataset]
    assert abs(p.mean() - tgt["prompt"][0]) / tgt["prompt"][0] < 0.15
    assert abs(np.percentile(p, 90) - tgt["prompt"][1]) / tgt["prompt"][1] < 0.20
    assert abs(o.mean() - tgt["output"][0]) / tgt["output"][0] < 0.15


def test_workload_poisson_rate():
    cm = CostModel(PROF, HW, seed=0)
    wl = make_workload(WorkloadSpec("sharegpt", qps=5.0, duration=400, seed=2), cm)
    rate = len(wl) / 400.0
    assert abs(rate - 5.0) < 0.75


# ---------------------------------------------------------------------------
# cost model regimes
# ---------------------------------------------------------------------------
def test_costmodel_decode_memory_bound():
    cm = CostModel(PROF, HW, noise_sigma=0)
    t_small = cm.latency([(1, 128)], noisy=False)
    t_big_batch = cm.latency([(1, 128)] * 32, noisy=False)
    # weight streaming dominates small decode batches: near-flat scaling
    assert t_big_batch < 4 * t_small


def test_costmodel_prefill_compute_bound():
    cm = CostModel(PROF, HW, noise_sigma=0)
    t1 = cm.latency([(1024, 0)], noisy=False)
    t2 = cm.latency([(4096, 0)], noisy=False)
    assert 3.0 < t2 / t1 < 5.0   # ~linear in tokens once compute-bound


def test_costmodel_attention_term_grows_with_context():
    cm = CostModel(PROF, HW, noise_sigma=0)
    assert cm.latency([(512, 16384)], noisy=False) > cm.latency([(512, 0)], noisy=False)


# ---------------------------------------------------------------------------
# simulator conservation
# ---------------------------------------------------------------------------
def test_simulator_conservation_and_completion():
    cm = CostModel(PROF, HW, seed=5)
    wl = make_workload(WorkloadSpec("sharegpt", qps=2.0, duration=30, seed=5), cm)
    sched = SlidingServeScheduler(max_budget=4096)
    sim = ServingSimulator(sched, cm, wl, kv_capacity_tokens=256 * 1024)
    res = sim.run()
    for r in res.requests:
        assert r.finish_time is not None, f"request {r.rid} never finished"
        assert r.prefilled == r.prompt_len
        assert r.generated == r.max_output
        assert len(r.token_times) == r.max_output
        assert all(b >= a for a, b in zip(r.token_times, r.token_times[1:]))
        assert r.token_times[0] >= r.arrival
    assert sim.alloc.free_blocks == sim.alloc.num_blocks  # all KV freed


def test_metrics_and_goodput_search():
    cm = CostModel(PROF, HW, seed=5)
    wl = make_workload(WorkloadSpec("sharegpt", qps=2.0, duration=30, seed=5), cm)
    sched = SlidingServeScheduler(max_budget=4096)
    sim = ServingSimulator(sched, cm, wl, kv_capacity_tokens=256 * 1024)
    res = sim.run()
    s = summarize(res.requests, res.duration)
    assert 0 <= s["violation_rate"] <= 1
    assert s["n_finished"] == s["n_requests"]
    cv = cumulative_violations(res.requests, res.duration)
    assert cv[-1][1] == sum(r.violations()["violated"] for r in res.requests)

    # goodput search against a synthetic monotone violation curve
    def fake_run(qps):
        return {"violation_rate": 0.0 if qps <= 3.3 else 0.5, "goodput_rps": qps}
    out = max_goodput(fake_run, 0.5, 8.0, iters=10)
    assert abs(out["qps"] - 3.3) < 0.1


def test_simulator_speculative_decode_conserves_and_saves_rounds():
    """spec_k > 0 prices decode rows as (1+k)-token verify rows and serves
    sampled accepted chains: every request must still finish with exactly
    max_output monotone tokens, KV must drain, and multi-token rounds must
    reduce the round count vs one-token decode on the same workload."""
    def sim_for(**kw):
        cm = CostModel(PROF, HW, seed=5)
        wl = make_workload(WorkloadSpec("sharegpt", qps=2.0, duration=30,
                                        seed=5), cm)
        sched = SlidingServeScheduler(max_budget=4096)
        return ServingSimulator(sched, cm, wl,
                                kv_capacity_tokens=256 * 1024, **kw)

    sim = sim_for(spec_k=4, spec_acceptance=0.5)
    res = sim.run()
    for r in res.requests:
        assert r.generated == r.max_output
        assert len(r.token_times) == r.max_output
        assert all(b >= a for a, b in zip(r.token_times, r.token_times[1:]))
    assert sim.alloc.free_blocks == sim.alloc.num_blocks
    assert sim.spec_rows > 0 and sim.spec_emitted > sim.spec_rows
    base = sim_for().run()
    assert res.iterations < base.iterations
    """Regression: ttft_slowdown once divided by a 1e-9 guard instead of the
    stamped exclusive-service baseline, reporting ~1e9 for every bench
    scenario. It is measured-TTFT / exclusive-prefill-time: >= 1 by
    construction (exclusive service lower-bounds TTFT) and small for a
    workload the scheduler actually keeps up with."""
    cm = CostModel(PROF, HW, seed=5)
    wl = make_workload(WorkloadSpec("sharegpt", qps=2.0, duration=30, seed=5),
                       cm)
    assert all(r.exclusive_ttft > 0.0 for r in wl), \
        "make_workload must stamp the exclusive-service baseline"
    sched = SlidingServeScheduler(max_budget=4096)
    sim = ServingSimulator(sched, cm, wl, kv_capacity_tokens=256 * 1024)
    res = sim.run()
    s = summarize(res.requests, res.duration)
    for key in ("ttft_slowdown_p50", "ttft_slowdown_p99"):
        assert 1.0 <= s[key] < 1e4, (key, s[key])
    # requests without a stamped baseline are excluded, not divided by 1e-9
    for r in res.requests:
        r.exclusive_ttft = 0.0
    s0 = summarize(res.requests, res.duration)
    assert math.isnan(s0["ttft_slowdown_p50"])
