"""Sharded paged serving: mesh parity, one-readback-per-round under
shard_map, and the SLO-class-aware admission/eviction satellites.

The multi-device cases run in a subprocess (forcing 8 host devices needs
XLA_FLAGS set before jax initializes; the tier-1 suite itself runs on one
device). Both partition strategies are exercised: the sequence-sharded
fallback (smoke llama's 2 KV heads don't divide a 4/8-wide ``model`` axis)
and the head-sharded path (a config with 8 KV heads). Greedy tokens must be
bit-identical to the single-device engine on 2x4 and 1x8 meshes, and the
zero-sync invariant — exactly one device→host readback per scheduler round —
must survive shard_map.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PARITY_SCRIPT = r'''
import dataclasses
import numpy as np
import jax
assert len(jax.devices()) == 8, jax.devices()
from repro.configs import get_config
from repro.core import SlidingServeScheduler
from repro.launch.mesh import make_serving_mesh
from repro.serving.engine import EngineCore
from repro.serving.server import InferenceServer

def run(mesh_spec, cfg, prompts=None, **engine_kw):
    mesh = make_serving_mesh(mesh_spec)
    # the small decode reserve makes the tiny prompt's block table narrower
    # than the mesh axis (nb < m), forcing the sequence-sharded fallback's
    # pad path through the engine.
    core = EngineCore(cfg, SlidingServeScheduler(max_budget=256,
                                                 max_iter_time=5.0),
                      cache_mode="paged", kv_capacity_tokens=2048,
                      decode_reserve_tokens=8, mesh=mesh, **engine_kw)
    server = InferenceServer(core)
    rng = np.random.default_rng(0)
    hs = []
    if prompts is None:
        prompts = [(rng.integers(1, core.cfg.vocab_size, n).astype(np.int32),
                    cls_)
                   for n, cls_ in [(37, "interactive"), (64, "batch"),
                                   (18, "standard"), (5, "interactive")]]
    for p, cls_ in prompts:
        hs.append(server.submit(p.copy(), slo_class=cls_, max_output=5))
    server.run(max_wall_s=200.0)
    st = core.stats
    # the zero-sync invariant survives jit(shard_map): one readback per round
    assert st.token_readbacks == st.iterations, (st.token_readbacks,
                                                 st.iterations)
    assert core.alloc.free_blocks == core.alloc.num_blocks, "KV pages leaked"
    return {h.rid: list(h.collected) for h in hs}, core

cfg = get_config("llama3.2-3b").smoke()          # Hkv=2: sequence fallback
# Exact token equality is guaranteed by construction for the head-sharded
# path (per-head math untouched). For the sequence-sharded fallback the
# partial-softmax combine regroups float sums, so exactness here is an
# empirical property of the pinned toolchain — it is the PR's acceptance
# criterion, and greedy argmax over the smoke vocab has ulp-scale margin.
base, _ = run(None, cfg)
assert all(len(t) == 5 for t in base.values()), base
for spec in ("2x4", "1x8"):
    got, core = run(spec, cfg)
    info = core.shard_info()
    assert info["kv_partition"] == "sequence", info
    assert got == base, (spec, got, base)

cfg8 = dataclasses.replace(cfg, num_heads=8, num_kv_heads=8)  # head-sharded
base8, _ = run(None, cfg8)
for spec in ("2x4", "1x8"):
    got, core = run(spec, cfg8)
    info = core.shard_info()
    assert info["kv_partition"] == "heads", info
    assert info["kv_shards"] == int(spec.split("x")[1]), info
    assert got == base8, (spec, got, base8)

# ---- speculative decoding across the mesh ------------------------------------
# periodic prompts give the n-gram drafter matches; greedy tokens must be
# bit-identical to the unspeculated single-device stream at any spec_k on
# every mesh, with the one-readback invariant intact (asserted inside run).
rng = np.random.default_rng(0)
loopy = []
for cls_ in ("interactive", "batch", "standard", "interactive"):
    seg = rng.integers(1, cfg.vocab_size, 12)
    loopy.append((np.tile(seg, 3).astype(np.int32), cls_))
spec_base, _ = run(None, cfg, prompts=loopy)
got, core = run(None, cfg, prompts=loopy, spec_k=4)
assert got == spec_base, "speculation changed single-device greedy tokens"
assert core.stats.spec_rounds > 0, "speculation never engaged"
for spec in ("2x4", "1x8"):
    got, core = run(spec, cfg, prompts=loopy, spec_k=4)
    assert core.stats.spec_rounds > 0, (spec, "speculation never engaged")
    assert got == spec_base, (spec, got, spec_base)

# ---- non-greedy sampling across the mesh -------------------------------------
# temperature/top-k with a fixed seed: the per-dispatch nonce sequence is
# deterministic, so the sampled stream must agree across meshes too (same
# empirical exactness caveat as the greedy sequence-sharded case above).
samp_kw = dict(temperature=0.7, top_k=20, sample_seed=11)
samp_base, _ = run(None, cfg, **samp_kw)
assert samp_base != base, "sampling reproduced greedy — nonce plumbing dead?"
for spec in ("2x4", "1x8"):
    got, _ = run(spec, cfg, **samp_kw)
    assert got == samp_base, (spec, got, samp_base)

# ---- ops-level parity vs the jnp oracles, under jit --------------------------
# covers what engine workloads may not reach: active sliding windows, logit
# softcap, and block tables narrower than the mesh axis (the pad path — this
# exact case once summed page ids across the unmentioned mesh axis).
import jax.numpy as jnp
from repro.kernels.paged_attention.ops import paged_attention_auto
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.paged_prefill_attention.ops import paged_prefill_attention_auto
from repro.kernels.paged_prefill_attention.ref import paged_prefill_attention_ref

rng = np.random.default_rng(1)
B, Hkv, G, D, Pg, ps = 3, 2, 2, 16, 32, 8
for n in (2, 6, 8):                          # 2 and 6 force the pad path
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(Hkv, Pg, ps, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(Hkv, Pg, ps, D)), jnp.float32)
    kvp = jnp.stack([kp, vp], axis=2)        # fused head-interleaved pool
    bt = jnp.asarray(rng.integers(0, Pg, size=(B, n)), jnp.int32)
    ln = jnp.asarray([1, min(11, n * ps), n * ps - 3], jnp.int32)
    qp = jnp.asarray(rng.normal(size=(B, 4, Hkv, G, D)), jnp.float32)
    rp = jnp.maximum(ln - 4, 0)
    for window, cap in ((0, 0.0), (7, 0.0), (0, 30.0), (7, 30.0)):
        ref = paged_attention_ref(q, kp, vp, bt, ln, scale=0.25,
                                  window=window, softcap=cap)
        refp = paged_prefill_attention_ref(qp, kp, vp, bt, rp, ln, scale=0.25,
                                           window=window, softcap=cap)
        for spec in ("2x4", "1x8"):
            mesh = make_serving_mesh(spec)
            got = jax.jit(lambda *a: paged_attention_auto(
                *a, scale=0.25, window=window, softcap=cap,
                mesh=mesh))(q, kvp, bt, ln)
            assert float(jnp.max(jnp.abs(got - ref))) < 2e-6, \
                ("decode", n, spec, window, cap)
            gotp = jax.jit(lambda *a: paged_prefill_attention_auto(
                *a, scale=0.25, window=window, softcap=cap,
                mesh=mesh))(qp, kvp, bt, rp, ln)
            assert float(jnp.max(jnp.abs(gotp - refp))) < 2e-6, \
                ("prefill", n, spec, window, cap)
print("SHARDED_PARITY_OK")
'''


def test_sharded_vs_single_device_parity_forced_host_mesh():
    """2x4 and 1x8 forced-host meshes produce bit-identical greedy tokens to
    the 1-device engine, on both KV partition strategies, with exactly one
    readback per round."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    env.pop("REPRO_FORCE_MESH", None)   # the script picks meshes explicitly
    out = subprocess.run([sys.executable, "-c", PARITY_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert "SHARDED_PARITY_OK" in out.stdout, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-4000:]}"


@pytest.fixture(scope="module")
def cfg():
    from repro.configs import get_config
    return get_config("llama3.2-3b").smoke()


def _engine(cfg, **kw):
    from repro.core import SlidingServeScheduler
    from repro.serving.engine import EngineCore
    kw.setdefault("cache_mode", "paged")
    return EngineCore(cfg, SlidingServeScheduler(max_budget=512,
                                                 max_iter_time=5.0), **kw)


def test_mesh_of_one_device_is_bit_identical(cfg):
    """A real 1x1 mesh (in-process, no forced devices) drives the whole
    sharded code path — device_put placement, pinned out_shardings, shard_map
    dispatch with a 1-wide axis — and must be bit-identical to the mesh-less
    engine, with the readback invariant intact."""
    from repro.launch.mesh import make_serving_mesh
    from repro.serving.request import Request

    def run(mesh):
        eng = _engine(cfg, kv_capacity_tokens=1024, mesh=mesh)
        reqs = [Request(rid=i, arrival=0.0, prompt_len=24 + 7 * i,
                        max_output=4, ttft_slo=60.0, tbt_slo=60.0)
                for i in range(3)]
        out = eng.serve(reqs, max_wall_s=120.0)
        assert not out["unfinished"]
        assert eng.stats.token_readbacks == eng.stats.iterations
        return out["outputs"]

    assert run(None) == run(make_serving_mesh("1x1"))


# =============================================================================
# SLO-class-aware admission / eviction satellites
# =============================================================================
def _req(rid, cls, prompt_len=32, max_output=4, arrival=0.0):
    from repro.serving.request import Request
    return Request(rid=rid, arrival=arrival, prompt_len=prompt_len,
                   max_output=max_output, ttft_slo=60.0, tbt_slo=60.0,
                   slo_class=cls)


def test_class_rank_mapping():
    from repro.serving.request import class_rank
    assert class_rank("interactive") < class_rank("standard") \
        < class_rank("batch")
    assert class_rank("unknown-tenant") == class_rank("standard")


def test_pick_victim_eligibility_filter():
    from repro.serving.block_allocator import BlockAllocator
    a = BlockAllocator(capacity_tokens=64, block_size=16)
    assert a.admit(1, 16) and a.admit(2, 16) and a.admit(3, 16)
    # rid 2 filtered out: the highest-priority *eligible* candidate wins
    vid = a.pick_victim(1, priority=lambda rid: rid,
                        eligible=lambda rid: rid != 3)
    assert vid == 2
    assert a.pick_victim(1, priority=lambda rid: rid,
                         eligible=lambda rid: False) is None


def test_admission_order_weights_slo_class(cfg):
    """With the free pool sized for one reservation, a later-queued
    interactive request is admitted ahead of an earlier-queued batch request
    (class-primary order); FIFO survives within a class."""
    eng = _engine(cfg, kv_capacity_tokens=64, page_size=16,
                  decode_reserve_tokens=0)          # 4 pages = one 64-prompt
    prompts = {i: np.zeros(64, np.int32) for i in range(3)}
    eng.add_request(_req(0, "batch", prompt_len=64), prompts[0])
    eng.add_request(_req(1, "batch", prompt_len=64), prompts[1])
    eng.add_request(_req(2, "interactive", prompt_len=64), prompts[2])
    eng._admit()
    assert [r.rid for r in eng._active] == [2], "interactive must admit first"
    assert [r.rid for r in eng._queued] == [0, 1], "batch keeps FIFO order"


def test_eviction_never_relegates_interactive_for_batch(cfg):
    """Tiny pool, one interactive + two batch requests decoding: decode
    growth must always pick a batch victim, the interactive stream must
    finish untouched, and the per-class stats must show it."""
    eng = _engine(cfg, kv_capacity_tokens=96, page_size=16,
                  decode_reserve_tokens=0)          # 6 pages; 3x2-page prompts
    reqs = [_req(0, "interactive", max_output=4),
            _req(1, "batch", max_output=4),
            _req(2, "batch", max_output=4)]
    out = eng.serve(reqs, max_wall_s=120.0)
    assert eng.stats.evictions > 0, "KV was never contended"
    assert "interactive" not in eng.stats.evicted_by_class, \
        eng.stats.evicted_by_class
    assert reqs[0].state.value == "finished"
    assert eng.stats.finished_by_class.get("interactive") == 1
    # pool fully released afterwards
    assert eng.alloc.free_blocks == eng.alloc.num_blocks


def test_summarize_by_class():
    from repro.serving.metrics import summarize_by_class
    rs = []
    for i, cls in enumerate(["interactive", "interactive", "batch"]):
        r = _req(i, cls, max_output=2)
        r.emit_token(0.1 + i)
        r.emit_token(0.2 + i)          # max_output=2 -> finished
        rs.append(r)
    out = summarize_by_class(rs, duration=10.0)
    assert set(out) == {"interactive", "batch"}
    assert out["interactive"]["n_requests"] == 2
    assert out["batch"]["n_finished"] == 1
    assert out["interactive"]["violation_rate"] == 0.0
