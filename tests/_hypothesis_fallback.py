"""Minimal random-sampling stand-in for ``hypothesis`` (used when the real
package is not installed — this container ships without it).

Implements just the surface the test suite uses: ``given`` with positional
strategies, ``settings(max_examples=..., deadline=...)``, and the strategies
``integers``, ``floats``, ``sampled_from``, ``tuples``, ``lists``. Examples
are drawn from a seeded RNG, so runs are deterministic; shrinking and the
database are (deliberately) not implemented. With real hypothesis installed,
the test modules import it instead of this shim.
"""
from __future__ import annotations

import functools
import inspect
import random
from typing import Any, Callable, List, Sequence

_DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (import as ``st``)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(options: Sequence[Any]) -> _Strategy:
        options = list(options)
        return _Strategy(lambda rng: rng.choice(options))

    @staticmethod
    def tuples(*elems: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))

    @staticmethod
    def lists(elem: _Strategy, *, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng: random.Random) -> List[Any]:
            n = rng.randint(min_size, max_size)
            return [elem.example(rng) for _ in range(n)]
        return _Strategy(draw)


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = getattr(runner, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for i in range(n):
                example = tuple(s.example(rng) for s in strats)
                try:
                    fn(*args, *example, **kwargs)
                except Exception as e:  # surface the failing example
                    raise AssertionError(
                        f"falsifying example (#{i}): {example!r}") from e
        runner._is_fallback_property_test = True
        # hide the wrapped signature: pytest must not see the strategy
        # parameters as fixtures (real hypothesis does the same)
        del runner.__wrapped__
        runner.__signature__ = inspect.Signature()
        return runner
    return deco


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
