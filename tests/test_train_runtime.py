"""Train substrate + runtime tests: optimizer descent, grad-accum equivalence,
checkpoint roundtrip/restart, compression error feedback, straggler/elastic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import RunCtx, init_params
from repro.runtime import compression
from repro.runtime.elastic import plan_remesh, resharding_plan
from repro.runtime.fault_tolerance import (HeartbeatMonitor, StragglerDetector,
                                           TrainingSupervisor)
from repro.train import checkpoint
from repro.train.data import DataConfig, PackedSyntheticData
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, lr_schedule
from repro.train.train_step import TrainConfig, init_train_state, make_train_step

RCTX = RunCtx(block_q=16, block_k=16, mlstm_block=16)


def test_adamw_descends_quadratic():
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0, total_steps=400)
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(loss(params)) < 1e-2


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.array(5))) == pytest.approx(0.5, rel=1e-3)
    assert float(lr_schedule(cfg, jnp.array(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr_schedule(cfg, jnp.array(100))) == pytest.approx(0.1, rel=1e-3)


def test_train_loss_decreases_and_grad_accum_matches():
    cfg = get_config("llama3.2-3b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    data = PackedSyntheticData(DataConfig(cfg.vocab_size, 64, 8, seed=1))
    tcfg1 = TrainConfig(optimizer=AdamWConfig(lr=1e-2, warmup_steps=0,
                                              total_steps=50, weight_decay=0.0))
    step1 = make_train_step(cfg, RCTX, tcfg1)
    state = init_train_state(cfg, params, tcfg1)
    p = params
    losses = []
    for i in range(8):
        batch = {"tokens": jnp.asarray(data.batch(i))}
        p, state, m = step1(p, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses

    # grad-accum(2) first step == full-batch first step
    tcfg2 = TrainConfig(optimizer=tcfg1.optimizer, grad_accum=2)
    step2 = make_train_step(cfg, RCTX, tcfg2)
    s1 = init_train_state(cfg, params, tcfg1)
    s2 = init_train_state(cfg, params, tcfg2)
    batch = {"tokens": jnp.asarray(data.batch(0))}
    p1, _, m1 = step1(params, s1, batch)
    p2, _, m2 = step2(params, s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3


def test_compression_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(333,)), jnp.float32)
    ef = jnp.zeros_like(g)
    q, scale, new_ef = compression.compress_leaf(g, ef)
    deq = compression.decompress_leaf(q, scale, g.shape, g.dtype)
    # int8 per-block quantization: ~0.8% of block max
    assert float(jnp.max(jnp.abs(deq - g))) < float(jnp.max(jnp.abs(g))) / 100
    # error feedback: repeated compression of a CONSTANT gradient averages out
    total = jnp.zeros_like(g)
    ef = jnp.zeros_like(g)
    steps = 64
    for _ in range(steps):
        q, scale, ef = compression.compress_leaf(g, ef)
        total = total + compression.decompress_leaf(q, scale, g.shape, g.dtype)
    np.testing.assert_allclose(np.asarray(total / steps), np.asarray(g),
                               atol=1e-3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16), "d": jnp.array(7)}}
    checkpoint.save(str(tmp_path), 42, tree, extra={"mesh": "2x2"})
    assert checkpoint.latest_step(str(tmp_path)) == 42
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = checkpoint.restore(str(tmp_path), 42, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert checkpoint.manifest_of(str(tmp_path), 42)["extra"]["mesh"] == "2x2"


def test_supervisor_restart_from_checkpoint(tmp_path):
    sup = TrainingSupervisor(str(tmp_path), save_every=5, async_save=False)

    def step_fn(state, step):
        return {"x": state["x"] + 1.0}

    failed = {"done": False}

    def fail_at(step):
        if step == 12 and not failed["done"]:
            failed["done"] = True
            return True
        return False

    state, end, restarts = sup.run(step_fn, {"x": jnp.zeros(())}, 0, 20,
                                   fail_at=fail_at)
    assert restarts == 1
    assert end == 20
    assert float(state["x"]) == 20.0   # replayed steps are idempotent


def test_heartbeat_and_straggler():
    hb = HeartbeatMonitor(["w0", "w1", "w2"], timeout=10.0)
    hb.beat("w0", now=100.0)
    hb.beat("w1", now=100.0)
    hb.beat("w2", now=89.0)
    dead = hb.check(now=100.5)
    assert dead == ["w2"]
    assert hb.alive_count() == 2

    sd = StragglerDetector(["w0", "w1", "w2"], threshold=1.5, min_samples=3)
    for _ in range(5):
        sd.record("w0", 1.0)
        sd.record("w1", 1.05)
        sd.record("w2", 2.5)
    assert sd.stragglers() == ["w2"]


def test_elastic_remesh_plan():
    p = plan_remesh(256)
    assert p.shape == (16, 16) and p.axes == ("data", "model")
    p2 = plan_remesh(512)
    assert p2.shape == (2, 16, 16) and p2.axes == ("pod", "data", "model")
    # losing 16 devices of 256: model axis preserved
    p3 = plan_remesh(240)
    assert p3.shape == (15, 16)
    assert not resharding_plan(p, p3)["tp_reshard_required"]
    # an awkward count falls back to a smaller model axis
    p4 = plan_remesh(24)
    assert p4.shape == (3, 8)
    assert resharding_plan(p, p4)["tp_reshard_required"]


def test_data_determinism_and_sharding():
    d = PackedSyntheticData(DataConfig(vocab_size=256, seq_len=32,
                                       global_batch=8, seed=3))
    full = d.batch(5, rank=0, world=1)
    halves = np.concatenate([d.batch(5, rank=0, world=2),
                             d.batch(5, rank=1, world=2)])
    np.testing.assert_array_equal(full, halves)
    np.testing.assert_array_equal(d.batch(5), d.batch(5))
    assert not np.array_equal(d.batch(5), d.batch(6))
