"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles,
executed in interpret mode on CPU (TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunked_prefill_attention.kernel import chunked_prefill_attention
from repro.kernels.chunked_prefill_attention.ref import chunked_prefill_attention_ref
from repro.kernels.mamba_scan.kernel import mamba_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.mlstm_chunkwise.kernel import mlstm_chunkwise
from repro.kernels.mlstm_chunkwise.ref import mlstm_ref
from repro.kernels.paged_attention.kernel import (paged_attention,
                                                  paged_attention_fused)
from repro.kernels.paged_attention.ref import (paged_attention_fused_ref,
                                               paged_attention_partial_ref,
                                               paged_attention_ref)
from repro.kernels.paged_prefill_attention.kernel import (
    paged_prefill_attention, paged_prefill_attention_fused)
from repro.kernels.paged_prefill_attention.ref import (
    paged_prefill_attention_fused_ref, paged_prefill_attention_partial_ref,
    paged_prefill_attention_ref)
from repro.kernels.ref_common import combine_partials, finalize_partials

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-5, rtol=2e-5) if dtype == jnp.float32 else dict(atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# chunked prefill attention
# ---------------------------------------------------------------------------
CPA_CASES = [
    # (B, H, Hkv, Sq, Sk, D, q_offset, causal, window, softcap, bq, bk)
    (1, 2, 2, 64, 64, 32, 0, True, 0, 0.0, 32, 32),
    (2, 4, 2, 128, 256, 64, 64, True, 0, 0.0, 64, 64),
    (2, 8, 2, 64, 512, 64, 448, True, 0, 0.0, 64, 128),   # deep prefix chunk
    (1, 4, 4, 128, 128, 64, 0, True, 96, 0.0, 64, 64),    # sliding window
    (1, 4, 4, 128, 128, 64, 0, True, 0, 50.0, 64, 64),    # softcap (gemma2)
    (2, 2, 1, 64, 128, 128, 0, False, 0, 0.0, 64, 64),    # cross/encoder
]


@pytest.mark.parametrize("case", CPA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunked_prefill_attention(case, dtype):
    B, H, Hkv, Sq, Sk, D, q_off, causal, window, cap, bq, bk = case
    q = jnp.asarray(RNG.normal(size=(B, H, Sq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Sk, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Sk, D)), dtype)
    lengths = jnp.asarray(RNG.integers(max(q_off + Sq, 1), Sk + 1, (B,)), jnp.int32)
    out = chunked_prefill_attention(
        q, k, v, lengths, scale=D ** -0.5, q_offset=q_off, causal=causal,
        window=window, softcap=cap, block_q=bq, block_k=bk, interpret=True)
    ref = chunked_prefill_attention_ref(
        q, k, v, lengths, scale=D ** -0.5, q_offset=q_off, causal=causal,
        window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# paged attention (decode)
# ---------------------------------------------------------------------------
PA_CASES = [
    # (B, H, Hkv, D, page_size, P_total, pages_per_seq, window, softcap)
    (2, 4, 4, 32, 16, 16, 4, 0, 0.0),
    (3, 8, 2, 64, 16, 32, 6, 0, 0.0),
    (2, 8, 8, 64, 32, 16, 4, 48, 0.0),
    (1, 4, 2, 128, 16, 8, 3, 0, 30.0),
]


@pytest.mark.parametrize("case", PA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention(case, dtype):
    B, H, Hkv, D, ps, P, n, window, cap = case
    q = jnp.asarray(RNG.normal(size=(B, H, D)), dtype)
    kp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), dtype)
    vp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), dtype)
    bt = jnp.asarray(RNG.integers(0, P, (B, n)), jnp.int32)
    lengths = jnp.asarray(RNG.integers(1, n * ps + 1, (B,)), jnp.int32)
    out = paged_attention(q, kp, vp, bt, lengths, scale=D ** -0.5,
                          window=window, softcap=cap, interpret=True)
    ref = paged_attention_ref(q, kp, vp, bt, lengths, scale=D ** -0.5,
                              window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# paged prefill attention (ragged chunked prefill over block tables)
# ---------------------------------------------------------------------------
PPA_CASES = [
    # (R, Sq, Hkv, G, D, page_size, P_total, pages_per_row, window, softcap, bq)
    (2, 32, 2, 2, 32, 16, 16, 6, 0, 0.0, 16),
    (3, 64, 2, 4, 64, 16, 32, 8, 0, 0.0, 32),   # ragged offsets, GQA
    (2, 32, 4, 1, 64, 16, 16, 4, 40, 0.0, 32),  # sliding window
    (1, 16, 2, 2, 128, 16, 8, 4, 0, 30.0, 16),  # softcap (gemma2)
    (4, 16, 2, 2, 32, 16, 16, 4, 0, 0.0, 16),   # has an all-padding row
]


@pytest.mark.parametrize("case", PPA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_prefill_attention(case, dtype):
    R, Sq, Hkv, G, D, ps, P, n, window, cap, bq = case
    q = jnp.asarray(RNG.normal(size=(R, Sq, Hkv, G, D)), dtype)
    kp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), dtype)
    vp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), dtype)
    bt = np.asarray(RNG.integers(0, P, (R, n)), np.int32)
    # every row prefills a chunk of (up to) Sq tokens at its own offset
    pos = np.asarray(RNG.integers(0, n * ps - Sq + 1, (R,)), np.int32)
    lens = pos + np.asarray(RNG.integers(1, Sq + 1, (R,)), np.int32)
    if R >= 4:
        # engine row-bucket padding: zero-length row addressing the trash page
        pos[-1], lens[-1] = 0, 0
        bt[-1] = P - 1
    out = paged_prefill_attention(
        q, kp, vp, jnp.asarray(bt), jnp.asarray(pos), jnp.asarray(lens),
        scale=D ** -0.5, window=window, softcap=cap, block_q=bq,
        interpret=True)
    ref = paged_prefill_attention_ref(
        q, kp, vp, jnp.asarray(bt), jnp.asarray(pos), jnp.asarray(lens),
        scale=D ** -0.5, window=window, softcap=cap)
    # compare only positions the engine consumes: q rows within the row's
    # valid post-chunk length (padding rows / tail produce discarded garbage)
    q_pos = pos[:, None] + np.arange(Sq)[None, :]
    valid = q_pos < lens[:, None]
    o, r_ = np.asarray(out, np.float32), np.asarray(ref, np.float32)
    tol = _tol(dtype)
    np.testing.assert_allclose(o[valid], r_[valid], **tol)


def test_paged_prefill_ref_matches_legacy_gather_path():
    """The jnp oracle must be bit-identical to the pre-kernel engine path
    (gather_pages + dense masked softmax) — the slot-vs-paged equivalence
    suite rides on this."""
    from repro.models.attention import gather_pages
    from repro.models import model as Mod

    class _Cfg:
        attn_logit_softcap = 0.0
    R, Sq, Hkv, G, D, ps, P, n = 2, 32, 2, 2, 32, 16, 16, 4
    q = jnp.asarray(RNG.normal(size=(R, Sq, Hkv, G, D)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), jnp.float32)
    bt = jnp.asarray(RNG.integers(0, P, (R, n)), jnp.int32)
    pos = jnp.asarray([0, 17], jnp.int32)
    lens = pos + jnp.asarray([Sq, Sq - 5], jnp.int32)
    ref = paged_prefill_attention_ref(q, kp, vp, bt, pos, lens,
                                      scale=D ** -0.5)
    k_all = gather_pages(kp, bt)
    v_all = gather_pages(vp, bt)
    legacy = Mod._chunk_attend(_Cfg(), None, q, k_all, v_all, pos, lens, 0,
                               scale=D ** -0.5)
    assert np.array_equal(np.asarray(ref), np.asarray(legacy))


# ---------------------------------------------------------------------------
# fused head-interleaved pool: double-buffered kernels + partial softmax
# ---------------------------------------------------------------------------
def _fused_pool(Hkv, P, ps, D, dtype):
    kp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), dtype)
    vp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), dtype)
    return kp, vp, jnp.stack([kp, vp], axis=2)


def _edge_lengths(B, n, ps):
    """Deterministic decode-length edge cases: full table (exactly on the
    last page boundary), exactly one page, shorter than one page, and an
    interior mid-page length for any remaining rows."""
    base = [n * ps, ps, max(ps - 3, 1), n * ps - ps // 2]
    return jnp.asarray([base[i % len(base)] for i in range(B)], jnp.int32)


FUSED_PA_CASES = [
    # (B, Hkv, G, D, page_size, P_total, pages_per_seq, window, softcap)
    (4, 4, 1, 32, 16, 16, 4, 0, 0.0),    # MHA (Hq/Hkv = 1)
    (4, 2, 4, 64, 16, 32, 6, 0, 0.0),    # GQA ratio 4
    (4, 1, 8, 64, 16, 16, 4, 0, 0.0),    # GQA ratio 8, single KV head
    (4, 2, 2, 64, 32, 16, 4, 48, 0.0),   # sliding window
    (4, 2, 2, 128, 16, 8, 3, 0, 30.0),   # softcap (gemma2)
    (4, 2, 2, 64, 16, 16, 4, 23, 30.0),  # window + softcap together
]


@pytest.mark.parametrize("case", FUSED_PA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_fused(case, dtype):
    """Double-buffered fused-layout decode kernel vs its jnp oracle, and the
    oracle vs the legacy split-pool oracle (bit-identical split views)."""
    B, Hkv, G, D, ps, P, n, window, cap = case
    H = Hkv * G
    q = jnp.asarray(RNG.normal(size=(B, H, D)), dtype)
    kp, vp, kvp = _fused_pool(Hkv, P, ps, D, dtype)
    bt = jnp.asarray(RNG.integers(0, P, (B, n)), jnp.int32)
    lengths = _edge_lengths(B, n, ps)
    out = paged_attention_fused(q, kvp, bt, lengths, scale=D ** -0.5,
                                window=window, softcap=cap, interpret=True)
    ref = paged_attention_fused_ref(q, kvp, bt, lengths, scale=D ** -0.5,
                                    window=window, softcap=cap)
    legacy = paged_attention_ref(q, kp, vp, bt, lengths, scale=D ** -0.5,
                                 window=window, softcap=cap)
    assert np.array_equal(np.asarray(ref), np.asarray(legacy)), \
        "fused oracle must be bit-identical to the split-pool oracle"
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("depth", [3, 4])
@pytest.mark.parametrize("case", FUSED_PA_CASES[:3])
def test_paged_attention_fused_dma_depth_parity(case, depth):
    """Deeper DMA rings only change the copy schedule: depth-N output must
    be bit-identical to the default double buffer."""
    B, Hkv, G, D, ps, P, n, window, cap = case
    H = Hkv * G
    q = jnp.asarray(RNG.normal(size=(B, H, D)), jnp.float32)
    _, _, kvp = _fused_pool(Hkv, P, ps, D, jnp.float32)
    bt = jnp.asarray(RNG.integers(0, P, (B, n)), jnp.int32)
    lengths = _edge_lengths(B, n, ps)
    base = paged_attention_fused(q, kvp, bt, lengths, scale=D ** -0.5,
                                 window=window, softcap=cap, interpret=True)
    deep = paged_attention_fused(q, kvp, bt, lengths, scale=D ** -0.5,
                                 window=window, softcap=cap,
                                 dma_depth=depth, interpret=True)
    assert np.array_equal(np.asarray(base), np.asarray(deep))


@pytest.mark.parametrize("case", FUSED_PA_CASES)
def test_paged_attention_partial_recombines_bit_exact(case):
    """finalize(partial kernel over the full page range) must equal the full
    fused kernel bit-exactly — same loop, same math, one deferred division.
    The partial jnp oracle must finalize to the full oracle the same way."""
    B, Hkv, G, D, ps, P, n, window, cap = case
    H = Hkv * G
    q = jnp.asarray(RNG.normal(size=(B, H, D)), jnp.float32)
    _, _, kvp = _fused_pool(Hkv, P, ps, D, jnp.float32)
    bt = jnp.asarray(RNG.integers(0, P, (B, n)), jnp.int32)
    lengths = _edge_lengths(B, n, ps)
    full = paged_attention_fused(q, kvp, bt, lengths, scale=D ** -0.5,
                                 window=window, softcap=cap, interpret=True)
    acc, m, l = paged_attention_fused(q, kvp, bt, lengths, scale=D ** -0.5,
                                      window=window, softcap=cap,
                                      partial=True, interpret=True)
    got = finalize_partials(acc, l, q.dtype)
    assert np.array_equal(np.asarray(got), np.asarray(full))
    # the full oracle normalizes before the V matmul (softmax-first), the
    # partial oracle divides after — same math, different op order, so the
    # oracle pair agrees to ulp scale rather than bitwise.
    racc, rm, rl = paged_attention_partial_ref(q, kvp, bt, lengths,
                                               scale=D ** -0.5, window=window,
                                               softcap=cap)
    rfull = paged_attention_fused_ref(q, kvp, bt, lengths, scale=D ** -0.5,
                                      window=window, softcap=cap)
    np.testing.assert_allclose(
        np.asarray(finalize_partials(racc, rl, q.dtype)), np.asarray(rfull),
        atol=2e-6, rtol=2e-6)


@pytest.mark.parametrize("shards", [2, 4])
def test_paged_attention_partial_cross_shard_combine(shards):
    """Sharding the block-table columns, computing per-shard partials with
    shard-local lengths (len - offset), and flash-combining matches the
    unsharded oracle — the sequence-sharded mesh fallback's exact math."""
    B, Hkv, G, D, ps, P, n = 3, 2, 2, 32, 8, 16, 8
    q = jnp.asarray(RNG.normal(size=(B, Hkv * G, D)), jnp.float32)
    _, _, kvp = _fused_pool(Hkv, P, ps, D, jnp.float32)
    bt = jnp.asarray(RNG.integers(0, P, (B, n)), jnp.int32)
    lengths = jnp.asarray([1, n * ps, n * ps // 2 + 3], jnp.int32)
    ref = paged_attention_fused_ref(q, kvp, bt, lengths, scale=D ** -0.5)
    n_loc = n // shards
    parts = []
    for i in range(shards):
        cols = bt[:, i * n_loc:(i + 1) * n_loc]
        parts.append(paged_attention_partial_ref(
            q, kvp, cols, lengths - i * n_loc * ps, scale=D ** -0.5))
    got = combine_partials(parts, q.dtype)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


FUSED_PPA_CASES = [
    # (R, Sq, Hkv, G, D, page_size, P_total, pages_per_row, window, cap, bq)
    (4, 16, 4, 1, 32, 16, 16, 6, 0, 0.0, 16),    # MHA
    (4, 32, 2, 4, 64, 16, 32, 8, 0, 0.0, 32),    # GQA ratio 4
    (4, 16, 1, 8, 64, 16, 16, 6, 0, 0.0, 16),    # GQA ratio 8
    (4, 32, 2, 2, 64, 16, 16, 6, 40, 0.0, 32),   # sliding window
    (4, 16, 2, 2, 128, 16, 8, 4, 0, 30.0, 16),   # softcap
    (4, 16, 2, 2, 32, 16, 16, 6, 23, 30.0, 16),  # window + softcap
]


def _prefill_edges(R, Sq, n, ps):
    """Row offsets/lengths hitting page-boundary and sub-page edges: a chunk
    ending exactly on a page boundary, a whole tiny prompt shorter than one
    page, a deep ragged chunk, and an all-padding row (trash page)."""
    pos = np.zeros((R,), np.int32)
    lens = np.zeros((R,), np.int32)
    pos[0], lens[0] = ps - Sq % ps if Sq % ps else 0, 0
    lens[0] = pos[0] + Sq                      # ends exactly on a boundary
    pos[1], lens[1] = 0, max(ps - 3, 1)        # shorter than one page
    pos[2], lens[2] = n * ps - Sq, n * ps      # deepest chunk, full table
    for i in range(3, R - 1):
        pos[i] = int(RNG.integers(0, n * ps - Sq + 1))
        lens[i] = pos[i] + int(RNG.integers(1, Sq + 1))
    pos[-1], lens[-1] = 0, 0                   # engine padding row
    return pos, lens


@pytest.mark.parametrize("case", FUSED_PPA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_prefill_attention_fused(case, dtype):
    R, Sq, Hkv, G, D, ps, P, n, window, cap, bq = case
    q = jnp.asarray(RNG.normal(size=(R, Sq, Hkv, G, D)), dtype)
    kp, vp, kvp = _fused_pool(Hkv, P, ps, D, dtype)
    bt = np.asarray(RNG.integers(0, P, (R, n)), np.int32)
    pos, lens = _prefill_edges(R, Sq, n, ps)
    bt[-1] = P - 1
    bt, pos, lens = jnp.asarray(bt), jnp.asarray(pos), jnp.asarray(lens)
    out = paged_prefill_attention_fused(
        q, kvp, bt, pos, lens, scale=D ** -0.5, window=window, softcap=cap,
        block_q=bq, interpret=True)
    ref = paged_prefill_attention_fused_ref(
        q, kvp, bt, pos, lens, scale=D ** -0.5, window=window, softcap=cap)
    legacy = paged_prefill_attention_ref(
        q, kp, vp, bt, pos, lens, scale=D ** -0.5, window=window, softcap=cap)
    assert np.array_equal(np.asarray(ref), np.asarray(legacy))
    q_pos = np.asarray(pos)[:, None] + np.arange(Sq)[None, :]
    valid = q_pos < np.asarray(lens)[:, None]
    np.testing.assert_allclose(np.asarray(out, np.float32)[valid],
                               np.asarray(ref, np.float32)[valid],
                               **_tol(dtype))


@pytest.mark.parametrize("depth", [4])
@pytest.mark.parametrize("case", FUSED_PPA_CASES[:3] + FUSED_PPA_CASES[3:4])
def test_paged_prefill_fused_dma_depth_parity(case, depth):
    """Ring depth must not change prefill output bits either — including the
    windowed case, whose loop starts at a dynamic ``j_lo``."""
    R, Sq, Hkv, G, D, ps, P, n, window, cap, bq = case
    q = jnp.asarray(RNG.normal(size=(R, Sq, Hkv, G, D)), jnp.float32)
    _, _, kvp = _fused_pool(Hkv, P, ps, D, jnp.float32)
    bt = jnp.asarray(RNG.integers(0, P, (R, n)), jnp.int32)
    pos, lens = _prefill_edges(R, Sq, n, ps)
    pos, lens = jnp.asarray(pos), jnp.asarray(lens)
    base = paged_prefill_attention_fused(
        q, kvp, bt, pos, lens, scale=D ** -0.5, window=window, softcap=cap,
        block_q=bq, interpret=True)
    deep = paged_prefill_attention_fused(
        q, kvp, bt, pos, lens, scale=D ** -0.5, window=window, softcap=cap,
        block_q=bq, dma_depth=depth, interpret=True)
    q_pos = np.asarray(pos)[:, None] + np.arange(Sq)[None, :]
    valid = q_pos < np.asarray(lens)[:, None]
    assert np.array_equal(np.asarray(base)[valid], np.asarray(deep)[valid])


@pytest.mark.parametrize("case", FUSED_PPA_CASES[:3])
def test_paged_prefill_partial_recombines_bit_exact(case):
    R, Sq, Hkv, G, D, ps, P, n, window, cap, bq = case
    q = jnp.asarray(RNG.normal(size=(R, Sq, Hkv, G, D)), jnp.float32)
    _, _, kvp = _fused_pool(Hkv, P, ps, D, jnp.float32)
    bt = jnp.asarray(RNG.integers(0, P, (R, n)), jnp.int32)
    pos, lens = _prefill_edges(R, Sq, n, ps)
    pos, lens = jnp.asarray(pos), jnp.asarray(lens)
    full = paged_prefill_attention_fused(
        q, kvp, bt, pos, lens, scale=D ** -0.5, window=window, softcap=cap,
        block_q=bq, interpret=True)
    acc, m, l = paged_prefill_attention_fused(
        q, kvp, bt, pos, lens, scale=D ** -0.5, window=window, softcap=cap,
        block_q=bq, partial=True, interpret=True)
    got = finalize_partials(acc, l, q.dtype)
    q_pos = np.asarray(pos)[:, None] + np.arange(Sq)[None, :]
    valid = q_pos < np.asarray(lens)[:, None]
    assert np.array_equal(np.asarray(got)[valid], np.asarray(full)[valid])
    # oracle pair: softmax-first vs divide-after — ulp-scale, not bitwise
    racc, rm, rl = paged_prefill_attention_partial_ref(
        q, kvp, bt, pos, lens, scale=D ** -0.5, window=window, softcap=cap)
    rfull = paged_prefill_attention_fused_ref(
        q, kvp, bt, pos, lens, scale=D ** -0.5, window=window, softcap=cap)
    np.testing.assert_allclose(
        np.asarray(finalize_partials(racc, rl, q.dtype))[valid],
        np.asarray(rfull)[valid], atol=2e-6, rtol=2e-6)


def test_write_pages_fused_matches_split_scatter():
    """One fused K+V scatter lands bytes exactly where two split-pool
    scatters would (slot addressing unchanged, trash slot included)."""
    from repro.models.attention import write_pages, write_pages_fused
    Hkv, P, ps, D, T = 2, 8, 16, 32, 40
    kp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), jnp.float32)
    kvp = jnp.stack([kp, vp], axis=2)
    k_new = jnp.asarray(RNG.normal(size=(1, T, Hkv, D)), jnp.float32)
    v_new = jnp.asarray(RNG.normal(size=(1, T, Hkv, D)), jnp.float32)
    slots = jnp.asarray(RNG.choice(P * ps, size=T, replace=False), jnp.int64)
    slots = slots.at[-1].set((P - 1) * ps)         # a trash-page write
    fused = write_pages_fused(kvp, k_new, v_new, slots)
    kp2 = write_pages(kp, k_new, slots)
    vp2 = write_pages(vp, v_new, slots)
    assert np.array_equal(np.asarray(fused),
                          np.asarray(jnp.stack([kp2, vp2], axis=2)))


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------
MS_CASES = [
    # (B, S, d_inner, n, chunk, d_tile)
    (1, 64, 32, 8, 32, 32),
    (2, 128, 64, 8, 32, 32),
    (2, 256, 128, 16, 64, 64),
]


@pytest.mark.parametrize("case", MS_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan(case, dtype):
    B, S, d, n, chunk, d_tile = case
    x = jnp.asarray(RNG.normal(size=(B, S, d)), dtype)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, d))) * 0.1, dtype)
    Bc = jnp.asarray(RNG.normal(size=(B, S, n)), dtype)
    Cc = jnp.asarray(RNG.normal(size=(B, S, n)), dtype)
    A = -jnp.exp(jnp.asarray(RNG.normal(size=(d, n)), jnp.float32))
    D = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
    out = mamba_scan(x, dt, Bc, Cc, A, D, chunk=chunk, d_tile=d_tile,
                     interpret=True)
    ref = mamba_scan_ref(x, dt, Bc, Cc, A, D)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-5,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 2e-5)


# ---------------------------------------------------------------------------
# mLSTM chunkwise
# ---------------------------------------------------------------------------
ML_CASES = [
    # (B, H, S, D, chunk)
    (1, 2, 64, 32, 32),
    (2, 3, 128, 32, 32),
    (2, 2, 128, 64, 64),
    (1, 4, 256, 32, 128),
]


@pytest.mark.parametrize("case", ML_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_chunkwise(case, dtype):
    B, H, S, D, chunk = case
    q = jnp.asarray(RNG.normal(size=(B, H, S, D)), dtype)
    k = (jnp.asarray(RNG.normal(size=(B, H, S, D)), dtype) / np.sqrt(D)).astype(dtype)
    v = jnp.asarray(RNG.normal(size=(B, H, S, D)), dtype)
    log_i = jnp.asarray(RNG.normal(size=(B, H, S)), jnp.float32)
    log_f = jax.nn.log_sigmoid(jnp.asarray(RNG.normal(size=(B, H, S)) + 3.0, jnp.float32))
    out = mlstm_chunkwise(q, k, v, log_i, log_f, chunk=chunk, interpret=True)
    ref = mlstm_ref(q, k, v, log_i, log_f)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 5e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 5e-4)


# ---------------------------------------------------------------------------
# cross-check: kernels vs the model layer implementations
# ---------------------------------------------------------------------------
def test_kernel_matches_model_blockwise_attention():
    """The serving model's blockwise attention and the Pallas kernel must
    agree (they are the same math reached via different tiling)."""
    from repro.models.attention import blockwise_attention
    B, Hkv, G, S, D = 1, 2, 2, 128, 64
    H = Hkv * G
    q = jnp.asarray(RNG.normal(size=(B, S, Hkv, G, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    model_out = blockwise_attention(q, k, v, scale=D ** -0.5, causal=True,
                                    block_q=64, block_k=64)
    qk = q.transpose(0, 2, 3, 1, 4).reshape(B, H, S, D)
    kk = k.transpose(0, 2, 1, 3)
    vk = v.transpose(0, 2, 1, 3)
    kernel_out = chunked_prefill_attention(
        qk, kk, vk, jnp.full((B,), S, jnp.int32), scale=D ** -0.5,
        causal=True, block_q=64, block_k=64, interpret=True)
    kernel_out = kernel_out.reshape(B, Hkv, G, S, D).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(model_out), np.asarray(kernel_out),
                               atol=2e-5, rtol=2e-5)
