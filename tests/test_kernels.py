"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles,
executed in interpret mode on CPU (TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunked_prefill_attention.kernel import chunked_prefill_attention
from repro.kernels.chunked_prefill_attention.ref import chunked_prefill_attention_ref
from repro.kernels.mamba_scan.kernel import mamba_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.mlstm_chunkwise.kernel import mlstm_chunkwise
from repro.kernels.mlstm_chunkwise.ref import mlstm_ref
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.paged_prefill_attention.kernel import paged_prefill_attention
from repro.kernels.paged_prefill_attention.ref import paged_prefill_attention_ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-5, rtol=2e-5) if dtype == jnp.float32 else dict(atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# chunked prefill attention
# ---------------------------------------------------------------------------
CPA_CASES = [
    # (B, H, Hkv, Sq, Sk, D, q_offset, causal, window, softcap, bq, bk)
    (1, 2, 2, 64, 64, 32, 0, True, 0, 0.0, 32, 32),
    (2, 4, 2, 128, 256, 64, 64, True, 0, 0.0, 64, 64),
    (2, 8, 2, 64, 512, 64, 448, True, 0, 0.0, 64, 128),   # deep prefix chunk
    (1, 4, 4, 128, 128, 64, 0, True, 96, 0.0, 64, 64),    # sliding window
    (1, 4, 4, 128, 128, 64, 0, True, 0, 50.0, 64, 64),    # softcap (gemma2)
    (2, 2, 1, 64, 128, 128, 0, False, 0, 0.0, 64, 64),    # cross/encoder
]


@pytest.mark.parametrize("case", CPA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunked_prefill_attention(case, dtype):
    B, H, Hkv, Sq, Sk, D, q_off, causal, window, cap, bq, bk = case
    q = jnp.asarray(RNG.normal(size=(B, H, Sq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Sk, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Sk, D)), dtype)
    lengths = jnp.asarray(RNG.integers(max(q_off + Sq, 1), Sk + 1, (B,)), jnp.int32)
    out = chunked_prefill_attention(
        q, k, v, lengths, scale=D ** -0.5, q_offset=q_off, causal=causal,
        window=window, softcap=cap, block_q=bq, block_k=bk, interpret=True)
    ref = chunked_prefill_attention_ref(
        q, k, v, lengths, scale=D ** -0.5, q_offset=q_off, causal=causal,
        window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# paged attention (decode)
# ---------------------------------------------------------------------------
PA_CASES = [
    # (B, H, Hkv, D, page_size, P_total, pages_per_seq, window, softcap)
    (2, 4, 4, 32, 16, 16, 4, 0, 0.0),
    (3, 8, 2, 64, 16, 32, 6, 0, 0.0),
    (2, 8, 8, 64, 32, 16, 4, 48, 0.0),
    (1, 4, 2, 128, 16, 8, 3, 0, 30.0),
]


@pytest.mark.parametrize("case", PA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention(case, dtype):
    B, H, Hkv, D, ps, P, n, window, cap = case
    q = jnp.asarray(RNG.normal(size=(B, H, D)), dtype)
    kp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), dtype)
    vp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), dtype)
    bt = jnp.asarray(RNG.integers(0, P, (B, n)), jnp.int32)
    lengths = jnp.asarray(RNG.integers(1, n * ps + 1, (B,)), jnp.int32)
    out = paged_attention(q, kp, vp, bt, lengths, scale=D ** -0.5,
                          window=window, softcap=cap, interpret=True)
    ref = paged_attention_ref(q, kp, vp, bt, lengths, scale=D ** -0.5,
                              window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# paged prefill attention (ragged chunked prefill over block tables)
# ---------------------------------------------------------------------------
PPA_CASES = [
    # (R, Sq, Hkv, G, D, page_size, P_total, pages_per_row, window, softcap, bq)
    (2, 32, 2, 2, 32, 16, 16, 6, 0, 0.0, 16),
    (3, 64, 2, 4, 64, 16, 32, 8, 0, 0.0, 32),   # ragged offsets, GQA
    (2, 32, 4, 1, 64, 16, 16, 4, 40, 0.0, 32),  # sliding window
    (1, 16, 2, 2, 128, 16, 8, 4, 0, 30.0, 16),  # softcap (gemma2)
    (4, 16, 2, 2, 32, 16, 16, 4, 0, 0.0, 16),   # has an all-padding row
]


@pytest.mark.parametrize("case", PPA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_prefill_attention(case, dtype):
    R, Sq, Hkv, G, D, ps, P, n, window, cap, bq = case
    q = jnp.asarray(RNG.normal(size=(R, Sq, Hkv, G, D)), dtype)
    kp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), dtype)
    vp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), dtype)
    bt = np.asarray(RNG.integers(0, P, (R, n)), np.int32)
    # every row prefills a chunk of (up to) Sq tokens at its own offset
    pos = np.asarray(RNG.integers(0, n * ps - Sq + 1, (R,)), np.int32)
    lens = pos + np.asarray(RNG.integers(1, Sq + 1, (R,)), np.int32)
    if R >= 4:
        # engine row-bucket padding: zero-length row addressing the trash page
        pos[-1], lens[-1] = 0, 0
        bt[-1] = P - 1
    out = paged_prefill_attention(
        q, kp, vp, jnp.asarray(bt), jnp.asarray(pos), jnp.asarray(lens),
        scale=D ** -0.5, window=window, softcap=cap, block_q=bq,
        interpret=True)
    ref = paged_prefill_attention_ref(
        q, kp, vp, jnp.asarray(bt), jnp.asarray(pos), jnp.asarray(lens),
        scale=D ** -0.5, window=window, softcap=cap)
    # compare only positions the engine consumes: q rows within the row's
    # valid post-chunk length (padding rows / tail produce discarded garbage)
    q_pos = pos[:, None] + np.arange(Sq)[None, :]
    valid = q_pos < lens[:, None]
    o, r_ = np.asarray(out, np.float32), np.asarray(ref, np.float32)
    tol = _tol(dtype)
    np.testing.assert_allclose(o[valid], r_[valid], **tol)


def test_paged_prefill_ref_matches_legacy_gather_path():
    """The jnp oracle must be bit-identical to the pre-kernel engine path
    (gather_pages + dense masked softmax) — the slot-vs-paged equivalence
    suite rides on this."""
    from repro.models.attention import gather_pages
    from repro.models import model as Mod

    class _Cfg:
        attn_logit_softcap = 0.0
    R, Sq, Hkv, G, D, ps, P, n = 2, 32, 2, 2, 32, 16, 16, 4
    q = jnp.asarray(RNG.normal(size=(R, Sq, Hkv, G, D)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(Hkv, P, ps, D)), jnp.float32)
    bt = jnp.asarray(RNG.integers(0, P, (R, n)), jnp.int32)
    pos = jnp.asarray([0, 17], jnp.int32)
    lens = pos + jnp.asarray([Sq, Sq - 5], jnp.int32)
    ref = paged_prefill_attention_ref(q, kp, vp, bt, pos, lens,
                                      scale=D ** -0.5)
    k_all = gather_pages(kp, bt)
    v_all = gather_pages(vp, bt)
    legacy = Mod._chunk_attend(_Cfg(), None, q, k_all, v_all, pos, lens, 0,
                               scale=D ** -0.5)
    assert np.array_equal(np.asarray(ref), np.asarray(legacy))


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------
MS_CASES = [
    # (B, S, d_inner, n, chunk, d_tile)
    (1, 64, 32, 8, 32, 32),
    (2, 128, 64, 8, 32, 32),
    (2, 256, 128, 16, 64, 64),
]


@pytest.mark.parametrize("case", MS_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan(case, dtype):
    B, S, d, n, chunk, d_tile = case
    x = jnp.asarray(RNG.normal(size=(B, S, d)), dtype)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, d))) * 0.1, dtype)
    Bc = jnp.asarray(RNG.normal(size=(B, S, n)), dtype)
    Cc = jnp.asarray(RNG.normal(size=(B, S, n)), dtype)
    A = -jnp.exp(jnp.asarray(RNG.normal(size=(d, n)), jnp.float32))
    D = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
    out = mamba_scan(x, dt, Bc, Cc, A, D, chunk=chunk, d_tile=d_tile,
                     interpret=True)
    ref = mamba_scan_ref(x, dt, Bc, Cc, A, D)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-5,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 2e-5)


# ---------------------------------------------------------------------------
# mLSTM chunkwise
# ---------------------------------------------------------------------------
ML_CASES = [
    # (B, H, S, D, chunk)
    (1, 2, 64, 32, 32),
    (2, 3, 128, 32, 32),
    (2, 2, 128, 64, 64),
    (1, 4, 256, 32, 128),
]


@pytest.mark.parametrize("case", ML_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_chunkwise(case, dtype):
    B, H, S, D, chunk = case
    q = jnp.asarray(RNG.normal(size=(B, H, S, D)), dtype)
    k = (jnp.asarray(RNG.normal(size=(B, H, S, D)), dtype) / np.sqrt(D)).astype(dtype)
    v = jnp.asarray(RNG.normal(size=(B, H, S, D)), dtype)
    log_i = jnp.asarray(RNG.normal(size=(B, H, S)), jnp.float32)
    log_f = jax.nn.log_sigmoid(jnp.asarray(RNG.normal(size=(B, H, S)) + 3.0, jnp.float32))
    out = mlstm_chunkwise(q, k, v, log_i, log_f, chunk=chunk, interpret=True)
    ref = mlstm_ref(q, k, v, log_i, log_f)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 5e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 5e-4)


# ---------------------------------------------------------------------------
# cross-check: kernels vs the model layer implementations
# ---------------------------------------------------------------------------
def test_kernel_matches_model_blockwise_attention():
    """The serving model's blockwise attention and the Pallas kernel must
    agree (they are the same math reached via different tiling)."""
    from repro.models.attention import blockwise_attention
    B, Hkv, G, S, D = 1, 2, 2, 128, 64
    H = Hkv * G
    q = jnp.asarray(RNG.normal(size=(B, S, Hkv, G, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    model_out = blockwise_attention(q, k, v, scale=D ** -0.5, causal=True,
                                    block_q=64, block_k=64)
    qk = q.transpose(0, 2, 3, 1, 4).reshape(B, H, S, D)
    kk = k.transpose(0, 2, 1, 3)
    vk = v.transpose(0, 2, 1, 3)
    kernel_out = chunked_prefill_attention(
        qk, kk, vk, jnp.full((B,), S, jnp.int32), scale=D ** -0.5,
        causal=True, block_q=64, block_k=64, interpret=True)
    kernel_out = kernel_out.reshape(B, Hkv, G, S, D).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(model_out), np.asarray(kernel_out),
                               atol=2e-5, rtol=2e-5)
