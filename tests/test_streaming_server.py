"""Online-API tests: the step-based EngineCore + streaming InferenceServer.

Pins the api_redesign acceptance properties:

* the ``serve()`` compatibility wrapper and a direct ``step()`` loop produce
  identical per-request greedy tokens AND identical readback counts;
* cancellation mid-prefill / mid-decode frees KV pages (and slot-mode slots)
  back to the allocator, leaves other streams' tokens unchanged, and emits
  an ABORTED event;
* EOS/stop-token termination is decided from the ids of the existing
  deferred one-readback-per-round flush — no extra device→host sync;
* the streaming frontend preserves the zero-sync property: exactly one host
  readback per executed scheduler round.
"""
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SlidingServeScheduler
from repro.serving.engine import EngineCore, EventKind, ServingEngine
from repro.serving.request import ReqState, Request
from repro.serving.server import SLO_CLASSES, InferenceServer


def _core(cfg, mode, **kw):
    kw.setdefault("max_budget", 256)
    budget = kw.pop("max_budget")
    sched = SlidingServeScheduler(max_budget=budget, max_iter_time=5.0)
    if mode == "paged":
        kw.setdefault("kv_capacity_tokens", 2048)
    else:
        kw.setdefault("max_slots", 4)
        kw.setdefault("max_len", 512)
    return EngineCore(cfg, sched, cache_mode=mode, seed=0, **kw)


def _mk_requests(spec, **req_kw):
    return [Request(rid=i, arrival=a, prompt_len=p, max_output=o,
                    ttft_slo=900.0, tbt_slo=900.0, **req_kw)
            for i, (a, p, o) in enumerate(spec)]


def _prompts(cfg, spec, seed=1):
    rng = np.random.default_rng(seed)
    return {i: rng.integers(1, cfg.vocab_size, p).astype(np.int32)
            for i, (_, p, _) in enumerate(spec)}


def _drive(core, max_wall_s=600.0):
    """Minimal direct step() driver (no server): the raw online loop."""
    events = []
    t_end = time.perf_counter() + max_wall_s
    while core.has_work() and time.perf_counter() < t_end:
        events += core.step()
        if core.progress != "executed":
            time.sleep(1e-3)
    return events


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama3.2-3b").smoke()


# ---------------------------------------------------------------------------
# serve() wrapper vs direct step() loop: bit-identical tokens, same syncs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["paged", "slot"])
def test_serve_wrapper_equals_step_loop(cfg, mode):
    spec = [(0.0, 24, 4), (0.0, 51, 4), (0.0, 37, 3)]
    prompts = _prompts(cfg, spec)

    eng_a = _core(cfg, mode)
    out = eng_a.serve(_mk_requests(spec),
                      {k: v.copy() for k, v in prompts.items()},
                      max_wall_s=900.0)
    assert not out["unfinished"]

    eng_b = _core(cfg, mode)
    for r in _mk_requests(spec):
        eng_b.add_request(r, prompts[r.rid].copy())
    events = _drive(eng_b)

    assert {k: out["outputs"][k] for k in prompts} == \
        {k: eng_b._tokens_out[k] for k in prompts}
    # identical sync behaviour: same executed rounds, same readback count
    assert eng_a.stats.iterations == eng_b.stats.iterations
    assert eng_a.stats.token_readbacks == eng_b.stats.token_readbacks
    if mode == "paged":
        assert eng_b.stats.token_readbacks == eng_b.stats.iterations
    # every request's lifecycle surfaced as events
    for rid in prompts:
        kinds = [e.kind for e in events if e.rid == rid]
        assert kinds.count(EventKind.FINISHED) == 1
        n_toks = len([k for k in kinds
                      if k in (EventKind.FIRST_TOKEN, EventKind.TOKEN)])
        assert n_toks == spec[rid][2]
        assert kinds.count(EventKind.FIRST_TOKEN) == 1


# ---------------------------------------------------------------------------
# cancellation: pages/slots freed, other streams unchanged, ABORTED emitted
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["paged", "slot"])
def test_cancel_mid_decode(cfg, mode):
    spec = [(0.0, 24, 6), (0.0, 51, 4), (0.0, 37, 3)]
    prompts = _prompts(cfg, spec)
    # reference: nobody cancelled
    ref = _core(cfg, mode).serve(_mk_requests(spec),
                                 {k: v.copy() for k, v in prompts.items()},
                                 max_wall_s=900.0)
    assert not ref["unfinished"]

    core = _core(cfg, mode)
    server = InferenceServer(core)
    handles = {r.rid: server.submit_request(r, prompts[r.rid].copy())
               for r in _mk_requests(spec)}
    victim = handles[0]
    # pump until the victim is mid-decode (>=1 token out, not finished)
    for _ in range(10_000):
        server.step()
        if len(victim.collected) >= 1 and not victim.finished:
            break
        if core.progress != "executed":
            time.sleep(1e-3)
    assert victim.collected and not victim.finished, "never reached mid-decode"
    victim.cancel()
    assert victim.aborted and victim.finish_reason == "aborted"
    assert any(e.kind is EventKind.ABORTED and e.rid == 0
               for e in server.events)
    server.run(max_wall_s=600.0)

    # other streams are token-identical to the uncancelled reference
    for rid in (1, 2):
        assert handles[rid].collected == ref["outputs"][rid]
        assert handles[rid].finish_reason == "length"
    # the victim's resources went back to the allocator immediately; after
    # the drain *everything* is back
    if mode == "paged":
        assert core.alloc.free_blocks == core.alloc.num_blocks
        core.alloc.check_invariants()
    else:
        assert sorted(core.free_slots) == list(range(core.max_slots))
    assert core.stats.aborted == 1
    assert not core.has_work()


def test_cancel_mid_prefill_frees_reservation(cfg):
    # small budget so the 120-token prompt needs several prefill rounds
    spec = [(0.0, 120, 4), (0.0, 32, 3)]
    prompts = _prompts(cfg, spec, seed=7)
    ref = _core(cfg, "paged", max_budget=48).serve(
        _mk_requests(spec), {k: v.copy() for k, v in prompts.items()},
        max_wall_s=900.0)
    assert not ref["unfinished"]

    core = _core(cfg, "paged", max_budget=48)
    server = InferenceServer(core)
    handles = {r.rid: server.submit_request(r, prompts[r.rid].copy())
               for r in _mk_requests(spec)}
    victim = handles[0].request
    for _ in range(10_000):
        server.step()
        if 0 < victim.prefilled < victim.prompt_len:
            break
        if core.progress != "executed":
            time.sleep(1e-3)
    assert 0 < victim.prefilled < victim.prompt_len, "never mid-prefill"
    blocks_held = core.alloc.owners[0].blocks
    assert blocks_held > 0
    free_before = core.alloc.free_blocks
    handles[0].cancel()
    # admission reserved prompt+decode headroom; all of it returns on abort
    assert core.alloc.free_blocks == free_before + blocks_held
    assert not handles[0].collected, "mid-prefill victim emitted tokens"
    server.run(max_wall_s=600.0)
    assert handles[1].collected == ref["outputs"][1]
    assert core.alloc.free_blocks == core.alloc.num_blocks
    assert not core.has_work()


# ---------------------------------------------------------------------------
# EOS / stop-token termination on the deferred readback
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["paged", "slot"])
def test_stop_token_terminates_early(cfg, mode):
    spec = [(0.0, 24, 6), (0.0, 51, 4)]
    prompts = _prompts(cfg, spec)
    ref_eng = _core(cfg, mode)
    ref = ref_eng.serve(_mk_requests(spec),
                        {k: v.copy() for k, v in prompts.items()},
                        max_wall_s=900.0)
    assert not ref["unfinished"]
    # stop on request 0's 2nd greedy token: generation must end right there,
    # with the stop token included as the final emitted token
    stop_tok = ref["outputs"][0][1]
    cut = ref["outputs"][0].index(stop_tok) + 1   # first occurrence wins

    eng = _core(cfg, mode)
    reqs = _mk_requests(spec)
    reqs[0].eos_id = stop_tok
    out = eng.serve(reqs, {k: v.copy() for k, v in prompts.items()},
                    max_wall_s=900.0)
    assert not out["unfinished"]
    assert out["outputs"][0] == ref["outputs"][0][:cut]
    assert reqs[0].state == ReqState.FINISHED
    assert reqs[0].generated == cut
    # the other stream is untouched
    assert out["outputs"][1] == ref["outputs"][1]
    if mode == "paged":
        # EOS rode the existing per-round readback: still exactly one
        # device->host sync per executed round, and no KV leak
        assert eng.stats.token_readbacks == eng.stats.iterations
        assert eng.alloc.free_blocks == eng.alloc.num_blocks
        eng.alloc.check_invariants()


def test_stop_ids_and_finish_reason_event(cfg):
    spec = [(0.0, 24, 6)]
    prompts = _prompts(cfg, spec)
    ref = _core(cfg, "paged").serve(
        _mk_requests(spec), {k: v.copy() for k, v in prompts.items()},
        max_wall_s=900.0)
    stop_tok = ref["outputs"][0][2]
    cut = ref["outputs"][0].index(stop_tok) + 1

    core = _core(cfg, "paged")
    server = InferenceServer(core)
    h = server.submit(prompts[0].copy(), slo_class="batch", max_output=6,
                      stop_ids=(stop_tok,))
    toks = h.result()
    assert toks == ref["outputs"][0][:cut]
    assert h.finish_reason == "stop"
    fin = [e for e in server.events if e.kind is EventKind.FINISHED]
    assert len(fin) == 1 and fin[0].reason == "stop"


# ---------------------------------------------------------------------------
# zero-sync property under the streaming frontend
# ---------------------------------------------------------------------------
def test_streaming_single_readback_per_round(cfg):
    """Exactly one token-id device->host readback per executed scheduler
    round while the engine is driven by submit/cancel streaming — the
    frontend must not add syncs to the paged hot path."""
    rng = np.random.default_rng(5)
    spec = [(0.0, int(rng.integers(16, 48)), 3) for _ in range(6)]
    prompts = _prompts(cfg, spec, seed=5)

    calls = []
    orig = EngineCore._readback

    def spy(self, arr):
        calls.append(np.shape(arr))
        return orig(self, arr)

    EngineCore._readback = spy
    try:
        core = _core(cfg, "paged", kv_capacity_tokens=4096)
        server = InferenceServer(core)
        handles = [server.submit(prompts[i].copy(), slo_class="interactive",
                                 max_output=spec[i][2]) for i in range(6)]
        outs = [h.result() for h in handles]
    finally:
        EngineCore._readback = orig
    st = core.stats
    assert len(calls) == st.token_readbacks == st.iterations, (
        len(calls), st.token_readbacks, st.iterations)
    assert st.max_concurrency > 1          # rounds really were batched
    # identical tokens to the offline serve() wrapper on the same workload
    ref = _core(cfg, "paged", kv_capacity_tokens=4096).serve(
        _mk_requests(spec), {k: v.copy() for k, v in prompts.items()},
        max_wall_s=900.0)
    assert outs == [ref["outputs"][i] for i in range(6)]


def test_slo_classes_map_to_deadlines(cfg):
    core = _core(cfg, "paged")
    server = InferenceServer(core)
    h = server.submit(np.arange(8, dtype=np.int32) + 1,
                      slo_class="interactive", max_output=2)
    cls = SLO_CLASSES["interactive"]
    assert h.request.ttft_slo == cls.ttft_slo
    assert h.request.tbt_slo == cls.tbt_slo
    assert h.request.slo_class == "interactive"
    with pytest.raises(KeyError):
        server.submit(np.arange(4, dtype=np.int32) + 1, slo_class="platinum")
    h.result()
    assert not core.has_work()


def test_serving_engine_alias_preserved():
    assert ServingEngine is EngineCore
