"""Unit tests for the paper's core: features, predictor, sorter, knapsack,
BatchConstructor, SlidingChunker, BatchForwarder."""
import itertools
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container ships without hypothesis: random-sampling shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.batch_constructor import batch_constructor, knapsack_01, value_fn
from repro.core.features import batch_features, scene_of
from repro.core.forwarder import BatchForwarder
from repro.core.predictor import BatchLatencyPredictor
from repro.core.sliding_chunker import sliding_chunker, window_bounds
from repro.core.sorter import normalized_urgency, priority_key, sort_candidates
from repro.serving.request import ReqState, Request


def mk_req(rid, arrival=0.0, prompt=100, out=10, ttft=1.0, tbt=0.04,
           prefilled=0, generated=0, guard=False):
    r = Request(rid=rid, arrival=arrival, prompt_len=prompt, max_output=out,
                ttft_slo=ttft, tbt_slo=tbt, guard=guard)
    r.prefilled = prefilled
    r.generated = generated
    if generated:
        r.state = ReqState.DECODING
        r.first_token_time = arrival + 0.1
        r.token_times = [arrival + 0.1 + 0.02 * k for k in range(generated)]
    elif prefilled:
        r.state = ReqState.PREFILLING
    return r


# ---------------------------------------------------------------------------
# features (Table 1)
# ---------------------------------------------------------------------------
def test_features_hand_case():
    batch = [(1, 100), (1, 200), (8, 50), (32, 0)]
    x = batch_features(batch)
    assert x[0] == 8 * 58 + 32 * 32          # x1 = sum c(u+c) over prefill
    assert x[1] == 64 + 1024                 # x2 = sum c^2
    assert x[2] == 350                       # x3 = total cached
    assert x[3] == 2                         # x4 = |D|
    assert x[4] == 300                       # x5 = decode context
    assert x[5] == 40                        # x6 = prefill tokens
    assert x[6] == 32                        # x7 = max chunk
    assert x[7] == 0                         # x8 = 0 without speculation
    assert scene_of(batch) == "mixed"
    assert scene_of([(1, 5)]) == "pure_decode"
    assert scene_of([(5, 0)]) == "pure_prefill"


def test_features_speculative_rows():
    # a verify row (1 pending + 3 drafts over 100 cached) is decode work:
    # it stays in D (x4/x5) and its extra cost lands in x8, not x1/x6.
    batch = [(4, 100, 3), (1, 200), (8, 50)]
    x = batch_features(batch)
    assert x[3] == 2                         # verify row counts as decode
    assert x[4] == 300
    assert x[0] == 8 * 58                    # prefill features see no drafts
    assert x[5] == 8
    assert x[7] == 3 * 104                   # (c-1) * (u+c) for the verify row
    assert scene_of([(4, 100, 3)]) == "pure_decode"
    # vectorized path agrees, including on mixed-width batches
    from repro.core.features import features_many
    X, scenes, csum = features_many([batch, [(1, 10)]])
    assert np.allclose(X[0], x)
    assert scenes[0] == "mixed" and scenes[1] == "pure_decode"
    assert csum[0] == 13 and csum[1] == 1


# ---------------------------------------------------------------------------
# predictor (§3.2)
# ---------------------------------------------------------------------------
def _linear_truth(batch):
    x = batch_features(batch)
    w = np.array([1e-9, 2e-9, 3e-8, 1e-4, 5e-9, 2e-6, 1e-7, 4e-9])
    return float(x @ w + 5e-3)


def test_predictor_learns_linear_truth():
    rng = np.random.default_rng(0)
    samples = []
    for _ in range(800):
        nd = int(rng.integers(0, 20))
        npf = int(rng.integers(0, 4))
        batch = [(1, int(rng.integers(1, 4096))) for _ in range(nd)]
        batch += [(int(rng.integers(2, 1024)), int(rng.integers(0, 4096)))
                  for _ in range(npf)]
        if not batch:
            continue
        samples.append((batch, _linear_truth(batch)))
    p = BatchLatencyPredictor()
    p.fit_offline(samples)
    ev = p.evaluate(samples)
    assert ev["r2"] > 0.995, ev      # paper Table 5 reports R^2 > 0.99
    assert ev["mae"] < 2e-4


def test_predictor_scene_experts_and_hot_swap():
    p = BatchLatencyPredictor(expert_threshold=16, refit_interval=32)
    rng = np.random.default_rng(1)
    for _ in range(200):
        nd = int(rng.integers(1, 20))
        batch = [(1, int(rng.integers(1, 2048))) for _ in range(nd)]
        p.observe(batch, _linear_truth(batch))
    assert p.models["pure_decode"] is not None       # expert active
    assert p.models["pure_prefill"] is None          # never seen -> global
    pred = p.predict([(128, 0)])
    assert pred > 0                                   # falls back to global


# ---------------------------------------------------------------------------
# sorter (§3.3)
# ---------------------------------------------------------------------------
def test_sorter_levels():
    t, rho = 10.0, 1000.0
    guard = mk_req(1, arrival=9.0, prompt=5000, ttft=100.0, guard=True)
    urgent = mk_req(2, arrival=9.9, prompt=2000, ttft=0.6)   # needs 2s, has 0.5s
    lazy_short = mk_req(3, arrival=0.0, prompt=50, ttft=100.0)
    lazy_long = mk_req(4, arrival=0.0, prompt=800, ttft=100.0)
    expired = mk_req(5, arrival=0.0, prompt=100, ttft=1.0)   # deadline long past
    order = sort_candidates([], [expired, lazy_long, lazy_short, urgent, guard],
                            t, rho, alpha=1.0)
    rids = [r.rid for r in order]
    assert rids[0] == 1          # safeguard first
    assert rids[1] == 2          # urgency second
    assert rids[2:4] == [3, 4]   # shorter remaining first
    assert rids[-1] == 5         # expired relegated last


def test_normalized_urgency_eq10():
    r = mk_req(1, arrival=0.0, prompt=1000, ttft=2.0)
    u = normalized_urgency(r, t=1.0, rho=1000.0)
    assert abs(u - 1000 / (1000 * 1.0)) < 1e-9


# ---------------------------------------------------------------------------
# knapsack (Alg. 2 inner)
# ---------------------------------------------------------------------------
@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 60), st.floats(0.01, 10.0)),
                min_size=0, max_size=10),
       st.integers(0, 150))
def test_knapsack_optimal_vs_bruteforce(items, capacity):
    chosen = knapsack_01(items, capacity, granularity=1)
    w = sum(items[i][0] for i in chosen)
    v = sum(items[i][1] for i in chosen)
    assert w <= capacity
    best = 0.0
    for mask in itertools.product([0, 1], repeat=len(items)):
        tw = sum(it[0] for it, m in zip(items, mask) if m)
        tv = sum(it[1] for it, m in zip(items, mask) if m)
        if tw <= capacity:
            best = max(best, tv)
    assert v >= best - 1e-9


def test_knapsack_granularity_never_overfills():
    items = [(17, 1.0), (33, 2.0), (15, 0.5)]
    chosen = knapsack_01(items, 48, granularity=16)
    assert sum(items[i][0] for i in chosen) <= 48


# ---------------------------------------------------------------------------
# forwarder
# ---------------------------------------------------------------------------
class TruthPredictor:
    def predict(self, batch):
        return _linear_truth(batch) if batch else 0.0


def test_forwarder_allocation_rule():
    F = BatchForwarder(TruthPredictor(), max_budget=4096)
    D = [mk_req(i, generated=2, prefilled=100) for i in range(3)]
    P = [mk_req(10, prompt=100), mk_req(11, prompt=500)]
    _, alloc = F.forward(D, P, 200)
    amap = {r.rid: n for r, n in alloc}
    assert all(amap[r.rid] == 1 for r in D)          # decodes get 1 token
    assert amap[10] == 100                            # first prefill completes
    assert amap[11] == 97                             # remainder chunked
    assert sum(amap.values()) == 200


def test_time_to_budget_inverts_pred():
    F = BatchForwarder(TruthPredictor(), max_budget=8192)
    D = [mk_req(i, generated=2, prefilled=100) for i in range(2)]
    P = [mk_req(10, prompt=8000)]
    for t_lim in [0.002, 0.01, 0.05]:
        b = F.time_to_budget(D, P, t_lim)
        floor = F.pred(len(D), D, P)
        if floor > t_lim:
            assert b == len(D)   # infeasible: best-effort decode-only floor
            continue
        assert F.pred(b, D, P) <= t_lim + 1e-12
        if b < 8192:
            assert F.pred(b + 16, D, P) > t_lim


# ---------------------------------------------------------------------------
# sliding chunker (Alg. 1)
# ---------------------------------------------------------------------------
def test_window_bounds_eq14_15():
    t = 100.0
    d1 = mk_req(1, arrival=99.0, ttft=0.5, tbt=0.04, generated=3, prefilled=10)
    d1.token_times = [99.5, 99.54, 99.58]
    t_cur, t_next = window_bounds([d1], t)
    # next token deadline: max(eq1, last + tbt) = max(99+0.5+3*0.04, 99.62)
    assert abs(t_cur - max(99.0 + 0.5 + 3 * 0.04, 99.62) + t) - t < 1e-9
    assert t_next >= 1e-4


def test_sliding_chunker_liveness_and_clamp():
    F = BatchForwarder(TruthPredictor(), max_budget=4096)
    P = [mk_req(10, prompt=3000, ttft=10.0)]
    b, alloc, pred = sliding_chunker([], P, 4096, 0.0, 0.05, 0.05, F)
    assert alloc, "must schedule work when slack exists"
    assert b <= F.time_to_budget([], P, 0.05)
    assert pred <= 0.05 + 1e-9


class ConvexPredictor:
    """Superlinear latency: balanced splits genuinely win."""
    def predict(self, batch):
        s = sum(c for c, _ in batch)
        return 1e-3 + 5e-8 * s * s


def test_sliding_chunker_balances_under_convexity():
    # Fig. 1 regime: current window generous (100ms), next window tight (5ms).
    # Greedy takes ~1407 tokens now and gets ~283 next; a balanced split
    # processes ~20% more total tokens, beating the deviation margin.
    F = BatchForwarder(ConvexPredictor(), max_budget=100_000)
    P = [mk_req(10, prompt=50_000, ttft=100.0)]
    b, alloc, _ = sliding_chunker([], P, 100_000, 0.0, 0.1, 0.005, F,
                                  ternary_stop=10)
    r0 = F.time_to_budget([], P, 0.1)
    assert b < r0, f"convex latency should trigger a below-greedy split ({b} vs {r0})"
    tokens_g = r0 + F.time_to_budget([], P, 0.005)
    assert b + b >= tokens_g, "balanced split should process more total tokens"


# ---------------------------------------------------------------------------
# batch constructor (Alg. 2)
# ---------------------------------------------------------------------------
def test_batch_constructor_no_risk_returns_none():
    F = BatchForwarder(TruthPredictor(), max_budget=512)
    P = [mk_req(10, prompt=100, ttft=100.0)]
    assert batch_constructor([], P, 512, 0.0, F) is None


def test_batch_constructor_rescues_anchor():
    F = BatchForwarder(TruthPredictor(), max_budget=4096)
    # Large pending batch makes T_full big; short-slack request is at risk.
    risky = mk_req(1, prompt=200, ttft=0.012)          # slack 12ms
    heavy = mk_req(2, prompt=4000, ttft=100.0)
    res = batch_constructor([], [risky, heavy], 4096, 0.0, F, granularity=8)
    assert res is not None
    budget, alloc = res
    rids = {r.rid: n for r, n in alloc}
    assert rids.get(1) == 200, "anchor gets its full remaining prefill"
    t_batch = F.predictor.predict([(n, r.context_len()) for r, n in alloc])
    assert t_batch <= 0.012 + 1e-9, "batch must fit in anchor slack"


def test_batch_constructor_comparer_prefers_more_completions():
    F = BatchForwarder(TruthPredictor(), max_budget=8192)
    # one long prompt inflates T_full past everyone's slack; the knapsack
    # should still pack several short completions alongside an anchor.
    reqs = [mk_req(i, prompt=80, ttft=0.02) for i in range(4)]
    reqs.append(mk_req(9, prompt=3000, ttft=0.02))
    res = batch_constructor([], reqs, 8192, 0.0, F, granularity=4,
                            decode_guard=False)
    assert res is not None
    _, alloc = res
    completed = [r for r, n in alloc if n > 1 and n == r.remaining_prefill()]
    assert len(completed) >= 2, "should pack multiple completions, not just one"
