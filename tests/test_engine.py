"""Real-execution engine test: SlidingServe drives actual JAX forwards and
the generated tokens must exactly match offline greedy decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SlidingServeScheduler
from repro.models.model import RunCtx, decode_step, init_cache, init_params, prefill
from repro.serving.engine import ServingEngine
from repro.serving.request import Request


def offline_greedy(cfg, params, prompt, n_out, rctx):
    cache = init_cache(cfg, 1, 512)
    logits, cache = prefill(cfg, params, jnp.asarray(prompt)[None], cache, rctx=rctx)
    toks = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(n_out - 1):
        logits, cache = decode_step(cfg, params, jnp.asarray([[toks[-1]]]), cache,
                                    pos, rctx=rctx)
        toks.append(int(jnp.argmax(logits[0])))
        pos += 1
    return toks


@pytest.mark.parametrize("arch", ["llama3.2-3b", "xlstm-125m"])
def test_engine_matches_offline_greedy(arch):
    cfg = get_config(arch).smoke()
    sched = SlidingServeScheduler(max_budget=256, max_iter_time=5.0)
    eng = ServingEngine(cfg, sched, max_slots=4, max_len=512)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, arrival=0.0, prompt_len=int(p), max_output=4,
                ttft_slo=900.0, tbt_slo=900.0)
        for i, p in enumerate([24, 51, 37])
    ]
    prompts = {r.rid: rng.integers(1, cfg.vocab_size, r.prompt_len).astype(np.int32)
               for r in reqs}
    # generous wall budget: CI boxes may be heavily contended and the
    # first xlstm chunk JIT can take minutes on a busy single core
    out = eng.serve(reqs, prompts, max_wall_s=900.0)
    assert not out["unfinished"], f"unfinished: {[r.rid for r in out['unfinished']]}"
    for r in reqs:
        expected = offline_greedy(cfg, eng.params, prompts[r.rid], r.max_output,
                                  eng.rctx)
        assert out["outputs"][r.rid] == expected, (
            f"rid={r.rid}: engine {out['outputs'][r.rid]} != offline {expected}")
    # paged mode fuses every prefill row in a decision into one dispatch, so
    # the floor is 1 call; the slot cache pays one dispatch per request.
    min_calls = 1 if eng.cache_mode == "paged" else len(reqs)
    assert eng.stats.iterations > 0 and eng.stats.prefill_calls >= min_calls
