"""Launch-stack smoke: lower+compile representative cells on a small forced
mesh in a subprocess (the dry-run needs its own XLA device-count flag)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CASES = [
    ("llama3.2-3b", "decode_32k"),
    ("qwen2-moe-a2.7b", "decode_32k"),   # EP shard_map path
    ("xlstm-125m", "train_4k"),          # DP-only tiny model
]


@pytest.mark.parametrize("arch,shape", CASES)
def test_dryrun_cell_small_mesh(arch, shape):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_FORCE_MESH="2x4",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = os.environ.get('XLA_FLAGS')\n"
        "from repro.launch import dryrun\n"
        f"res = dryrun.run_cell({arch!r}, {shape!r}, multi_pod=False)\n"
        "assert res['status'] == 'ok', res\n"
        "print('CELL_OK', res['flops_per_dev'])\n"
    )
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert "CELL_OK" in out.stdout, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"


def test_dryrun_multipod_small_mesh():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_FORCE_MESH="2x2x4",
               XLA_FLAGS="--xla_force_host_platform_device_count=16")
    code = (
        "import os\n"
        "from repro.launch import dryrun\n"
        "res = dryrun.run_cell('gemma2-2b', 'decode_32k', multi_pod=True)\n"
        "assert res['status'] == 'ok', res\n"
        "print('CELL_OK')\n"
    )
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert "CELL_OK" in out.stdout, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
