"""Speculative multi-token decoding on the zero-sync paged path.

The non-negotiable bar: speculation is a *schedule* change, never a *math*
change. Greedy token streams must be bit-identical at ``spec_k=0`` and at any
``spec_k``, the one-readback-per-round invariant must survive verify rows,
and rejected drafts must leave the allocator exactly as a plain decode would.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SlidingServeScheduler
from repro.serving.drafter import DrafterBase, NGramDrafter
from repro.serving.engine import ServingEngine
from repro.serving.request import Request


def _mk_requests(spec, **kw):
    return [Request(rid=i, arrival=a, prompt_len=p, max_output=o,
                    ttft_slo=900.0, tbt_slo=900.0, **kw)
            for i, (a, p, o) in enumerate(spec)]


def _serve(cfg, prompts, spec, req_kw=None, **engine_kw):
    reqs = _mk_requests(spec, **(req_kw or {}))
    sched = SlidingServeScheduler(max_budget=256, max_iter_time=5.0)
    eng = ServingEngine(cfg, sched, seed=0, **engine_kw)
    out = eng.serve(reqs, {k: v.copy() for k, v in prompts.items()},
                    max_wall_s=900.0)
    return eng, out


def _loopy_prompts(cfg, n, prompt_len=32, period=12, seed=11):
    """Periodic prompts: the n-gram drafter's best case (the model's output
    need not follow the pattern — acceptance just has to be plausible)."""
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(n):
        base = rng.integers(1, cfg.vocab_size, period)
        out[i] = np.tile(base, prompt_len // period + 1)[:prompt_len].astype(
            np.int32)
    return out


# ---------------------------------------------------------------------------
# drafter unit layer
# ---------------------------------------------------------------------------
def test_ngram_drafter_proposes_continuation():
    d = NGramDrafter(max_ngram=3, min_ngram=1)
    ctx = np.asarray([5, 6, 7, 8, 5, 6, 7], np.int32)
    got = d.propose(ctx, 3)
    # trailing (5,6,7) matched at position 0 -> continuation (8, 5, 6)
    assert got is not None and got.tolist() == [8, 5, 6]


def test_ngram_drafter_prefers_longest_and_most_recent_match():
    d = NGramDrafter(max_ngram=3, min_ngram=1)
    # trailing 3-gram (1,2,3) occurs twice; most recent prior match (index 4)
    # wins, so the draft continues with 9, not 4.
    ctx = np.asarray([1, 2, 3, 4, 1, 2, 3, 9, 1, 2, 3], np.int32)
    got = d.propose(ctx, 1)
    assert got is not None and got.tolist() == [9]


def test_ngram_drafter_no_match_returns_none():
    d = NGramDrafter()
    assert d.propose(np.arange(1, 9, dtype=np.int32), 4) is None
    assert d.propose(np.asarray([3], np.int32), 4) is None
    assert d.propose(np.asarray([7, 7, 7], np.int32), 0) is None


# ---------------------------------------------------------------------------
# engine: parity + invariants
# ---------------------------------------------------------------------------
def test_spec_greedy_parity_and_single_readback():
    """Bit-identical greedy tokens at spec_k=0 vs spec_k=4, exactly one
    readback per executed round either way, and real multi-token rounds."""
    cfg = get_config("llama3.2-3b").smoke()
    spec = [(0.0, 32, 6) for _ in range(4)]
    prompts = _loopy_prompts(cfg, 4)

    calls = []
    orig = ServingEngine._readback

    def spy(self, arr):
        calls.append(np.shape(arr))
        return orig(self, arr)

    ServingEngine._readback = spy
    try:
        eng, out = _serve(cfg, prompts, spec, cache_mode="paged",
                          kv_capacity_tokens=4096, spec_k=4)
    finally:
        ServingEngine._readback = orig
    assert not out["unfinished"]
    st = eng.stats
    assert len(calls) == st.token_readbacks == st.iterations, (
        len(calls), st.token_readbacks, st.iterations)
    info = eng.spec_info()
    assert info["spec_rounds"] > 0 and info["draft_tokens"] > 0
    assert info["acceptance_rate"] > 0.0, info
    assert info["tokens_per_verify_row"] > 1.0, info
    assert eng.alloc.free_blocks == eng.alloc.num_blocks

    ref_eng, ref = _serve(cfg, prompts, spec, cache_mode="paged",
                          kv_capacity_tokens=4096, spec_k=0)
    assert not ref["unfinished"]
    assert out["outputs"] == ref["outputs"], "speculation changed the stream"
    # accepted drafts never cost extra rounds (short streams may not save a
    # whole round; tokens_per_verify_row > 1 above is the per-row win)
    assert eng.stats.iterations <= ref_eng.stats.iterations


def test_spec_parity_on_nonrepetitive_prompts():
    """Adversarial drafter input (random prompts, mostly rejections): the
    stream must still be bit-identical and the engine must finish."""
    cfg = get_config("llama3.2-3b").smoke()
    rng = np.random.default_rng(7)
    spec = [(0.0, int(rng.integers(16, 48)), 4) for _ in range(6)]
    prompts = {i: rng.integers(1, cfg.vocab_size, p).astype(np.int32)
               for i, (_, p, _) in enumerate(spec)}
    eng, out = _serve(cfg, prompts, spec, cache_mode="paged",
                      kv_capacity_tokens=4096, spec_k=4)
    _, ref = _serve(cfg, prompts, spec, cache_mode="paged",
                    kv_capacity_tokens=4096, spec_k=0)
    assert not out["unfinished"] and not ref["unfinished"]
    assert out["outputs"] == ref["outputs"]
    assert eng.alloc.free_blocks == eng.alloc.num_blocks


def test_spec_legacy_sync_mode_same_tokens():
    """overlap=False (the multi-readback A/B mode) with speculation on still
    produces the identical greedy stream."""
    cfg = get_config("llama3.2-3b").smoke()
    spec = [(0.0, 32, 5) for _ in range(3)]
    prompts = _loopy_prompts(cfg, 3, seed=13)
    _, out = _serve(cfg, prompts, spec, cache_mode="paged",
                    kv_capacity_tokens=4096, spec_k=4, overlap=False)
    _, ref = _serve(cfg, prompts, spec, cache_mode="paged",
                    kv_capacity_tokens=4096, spec_k=0)
    assert not out["unfinished"] and not ref["unfinished"]
    assert out["outputs"] == ref["outputs"]


def test_spec_max_output_truncates_mid_burst():
    """A verify row can accept past the request's budget; emission must stop
    at exactly max_output and match the unspeculated stream."""
    cfg = get_config("llama3.2-3b").smoke()
    spec = [(0.0, 32, 2) for _ in range(3)]
    prompts = _loopy_prompts(cfg, 3, seed=17)
    eng, out = _serve(cfg, prompts, spec, cache_mode="paged",
                      kv_capacity_tokens=4096, spec_k=4)
    _, ref = _serve(cfg, prompts, spec, cache_mode="paged",
                    kv_capacity_tokens=4096, spec_k=0)
    assert not out["unfinished"]
    assert out["outputs"] == ref["outputs"]
    for r in out["finished"]:
        assert r.generated == 2 and len(out["outputs"][r.rid]) == 2
    assert eng.alloc.free_blocks == eng.alloc.num_blocks


def test_spec_stop_token_terminates_mid_burst():
    """Make a token the reference stream emits a stop token: the speculative
    run must cut the burst at the same position with reason 'stop'."""
    cfg = get_config("llama3.2-3b").smoke()
    spec = [(0.0, 32, 8) for _ in range(2)]
    prompts = _loopy_prompts(cfg, 2, seed=19)
    _, ref = _serve(cfg, prompts, spec, cache_mode="paged",
                    kv_capacity_tokens=4096, spec_k=0)
    # pick a token the reference emits mid-stream (not the first token)
    stream = next(toks for toks in ref["outputs"].values() if len(toks) > 2)
    stop = int(stream[2])
    req_kw = {"stop_ids": (stop,)}
    eng, out = _serve(cfg, prompts, spec, req_kw=req_kw, cache_mode="paged",
                      kv_capacity_tokens=4096, spec_k=4)
    _, ref2 = _serve(cfg, prompts, spec, req_kw=req_kw, cache_mode="paged",
                     kv_capacity_tokens=4096, spec_k=0)
    assert not out["unfinished"] and not ref2["unfinished"]
    assert out["outputs"] == ref2["outputs"]
    # the stop token really fired: some stream ended before its budget
    assert any(r.generated < r.max_output for r in out["finished"])
    assert eng.alloc.free_blocks == eng.alloc.num_blocks


def test_spec_survives_eviction_pressure():
    """Contended KV with speculation on: evictions + draft rollback never
    corrupt the stream (recompute reproduces the uncontended tokens), and
    every page is returned."""
    cfg = get_config("llama3.2-3b").smoke()
    spec = [(0.0, 60, 6) for _ in range(4)]
    prompts = _loopy_prompts(cfg, 4, prompt_len=60, seed=23)
    _, ref = _serve(cfg, prompts, spec, cache_mode="paged",
                    kv_capacity_tokens=4096, spec_k=0)
    eng, out = _serve(cfg, prompts, spec, cache_mode="paged",
                      kv_capacity_tokens=256, page_size=16,
                      decode_reserve_tokens=0, spec_k=4)
    assert not out["unfinished"]
    assert eng.stats.evictions > 0, "KV was never contended"
    assert out["outputs"] == ref["outputs"]
    eng.alloc.check_invariants()
    assert eng.alloc.free_blocks == eng.alloc.num_blocks


def test_spec_class_caps_and_pluggable_drafter():
    """Per-class spec_k caps flow through, and a custom DrafterBase plugs in
    (a constant-token drafter: everything it proposes gets rejected, which
    must not perturb the stream)."""
    cfg = get_config("llama3.2-3b").smoke()

    class ConstantDrafter(DrafterBase):
        def propose(self, context, k):
            return np.full(k, 3, np.int32)

    spec = [(0.0, 32, 4) for _ in range(3)]
    prompts = _loopy_prompts(cfg, 3, seed=29)
    eng, out = _serve(cfg, prompts, spec, cache_mode="paged",
                      kv_capacity_tokens=4096, spec_k=4,
                      drafter=ConstantDrafter(),
                      spec_class_caps={1: 2})
    _, ref = _serve(cfg, prompts, spec, cache_mode="paged",
                    kv_capacity_tokens=4096, spec_k=0)
    assert not out["unfinished"]
    assert out["outputs"] == ref["outputs"]
    info = eng.spec_info()
    # dialogue-class default rank is 1 -> capped at 2 drafts per row
    if info["verify_rows"]:
        assert info["draft_tokens"] <= 2 * info["verify_rows"]


# ---------------------------------------------------------------------------
# sampling determinism
# ---------------------------------------------------------------------------
def test_sampled_serve_is_deterministic_and_differs_from_greedy():
    cfg = get_config("llama3.2-3b").smoke()
    rng = np.random.default_rng(31)
    spec = [(0.0, 24, 6) for _ in range(3)]
    prompts = {i: rng.integers(1, cfg.vocab_size, 24).astype(np.int32)
               for i in range(3)}
    kw = dict(cache_mode="paged", kv_capacity_tokens=4096,
              temperature=0.8, top_k=40, sample_seed=123)
    _, a = _serve(cfg, prompts, spec, **kw)
    _, b = _serve(cfg, prompts, spec, **kw)
    assert not a["unfinished"] and a["outputs"] == b["outputs"]
    _, g = _serve(cfg, prompts, spec, cache_mode="paged",
                  kv_capacity_tokens=4096)
    assert a["outputs"] != g["outputs"], \
        "t=0.8 sampling reproduced greedy exactly — nonce plumbing dead?"
    # a different seed must change the stream
    kw2 = dict(kw, sample_seed=124)
    _, c = _serve(cfg, prompts, spec, **kw2)
    assert a["outputs"] != c["outputs"]


def test_sampled_spec_run_is_deterministic():
    """Speculation + sampling: the accept rule compares sampled choices, so
    the stream stays exact w.r.t. the nonce sequence — two identical runs
    must agree token-for-token and keep the one-readback invariant."""
    cfg = get_config("llama3.2-3b").smoke()
    spec = [(0.0, 32, 5) for _ in range(3)]
    prompts = _loopy_prompts(cfg, 3, seed=37)
    kw = dict(cache_mode="paged", kv_capacity_tokens=4096, spec_k=4,
              temperature=0.7, top_k=20, sample_seed=9)
    eng, a = _serve(cfg, prompts, spec, **kw)
    _, b = _serve(cfg, prompts, spec, **kw)
    assert not a["unfinished"]
    assert a["outputs"] == b["outputs"]
    assert eng.stats.token_readbacks == eng.stats.iterations
