"""Paper Table 5: Batch Latency Predictor fidelity (MAE / RMSE / R^2).

The paper evaluates on three GPU configs; we evaluate against the analytic
ground-truth executor for three TPU v5e model-parallel configurations.
Training follows the paper's protocol: offline init on profiled batches, then
online incremental updates from a real serving trace; evaluation is on a
held-out trace slice.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import QUICK, emit
from repro.configs.bench_models import BENCH_MODELS
from repro.core.predictor import BatchLatencyPredictor
from repro.core import SlidingServeScheduler
from repro.serving.costmodel import CostModel, HardwareSpec, ModelProfile
from repro.serving.simulator import ServingSimulator
from repro.serving.workloads import WorkloadSpec, make_workload

CONFIGS = [("v5e-tp1", 1), ("v5e-tp4", 4), ("v5e-tp8", 8)]


def trace_samples(chips: int, duration: float, qps_scale: float, seed: int = 9):
    """Harvest (batch, noisy latency, clean latency) from a live simulation."""
    cfg = BENCH_MODELS["qwen2.5-7b"]
    prof = ModelProfile.from_config(cfg)
    cm = CostModel(prof, HardwareSpec(chips=chips), seed=seed)
    wl = make_workload(WorkloadSpec("mixed-v1", 2.5 * qps_scale, duration, seed=seed), cm)
    sched = SlidingServeScheduler(max_budget=4096)
    samples = []
    orig = sched.observe
    def spy(batch, latency):
        samples.append((list(batch), latency, cm.latency(batch, noisy=False)))
        orig(batch, latency)
    sched.observe = spy
    ServingSimulator(sched, cm, wl, kv_capacity_tokens=512 * 1024).run()
    return samples


def main(quick: bool = QUICK) -> dict:
    duration = 60.0 if quick else 180.0
    results = {}
    for name, chips in CONFIGS:
        samples = trace_samples(chips, duration, qps_scale=max(1.0, chips * 0.75))
        if len(samples) < 200:
            samples = trace_samples(chips, duration * 2, qps_scale=max(1.0, chips))
        split = int(0.7 * len(samples))
        train, test = samples[:split], samples[split:]
        p = BatchLatencyPredictor()
        p.fit_offline([(b, y) for b, y, _ in train[: len(train) // 2]])
        for batch, y, _ in train[len(train) // 2:]:
            p.observe(batch, y)      # online incremental phase
        ev = p.evaluate([(b, y) for b, y, _ in test])
        # fidelity vs the *mean* latency: strips the irreducible runtime
        # jitter (the paper's GPUs traces have far larger between-batch
        # variance, so their R^2 vs raw runtimes is not noise-limited)
        ev_clean = p.evaluate([(b, yc) for b, _, yc in test])
        results[name] = {**ev, "r2_clean": ev_clean["r2"]}
        emit(f"predictor/{name}/mae_ms", f"{ev['mae'] * 1e3:.3f}", "paper: 2.5-2.7ms")
        emit(f"predictor/{name}/rmse_ms", f"{ev['rmse'] * 1e3:.3f}", "paper: 4.1-4.3ms")
        emit(f"predictor/{name}/r2", f"{ev['r2']:.4f}", "vs noisy runtimes")
        emit(f"predictor/{name}/r2_clean", f"{ev_clean['r2']:.4f}", "paper: >0.99")
        emit(f"predictor/{name}/n_test", ev["n"], "")
    results["microbench"] = microbench()
    return results


def microbench(n: int = 4000, seed: int = 11) -> dict:
    """Predictor-overhead guardrail: the vectorized bulk paths
    (``predict_many`` / ``fit_offline``) must beat their per-sample loop
    equivalents, and a single online ``observe`` must stay far below the
    serve loop's per-round budget — the predictor must never re-enter the
    hot loop as a host bottleneck."""
    rng = np.random.default_rng(seed)
    samples = []
    for _ in range(n):
        k = int(rng.integers(1, 9))
        batch = [(int(rng.integers(0, 2)) if rng.random() < 0.6
                  else int(rng.integers(2, 512)), int(rng.integers(0, 4096)))
                 for _ in range(k)]
        samples.append((batch, float(rng.random() * 0.1)))
    p = BatchLatencyPredictor()
    p.fit_offline(samples[: n // 2])

    t0 = time.perf_counter()
    yh_loop = np.asarray([p.predict(b) for b, _ in samples])
    t_loop = time.perf_counter() - t0
    t0 = time.perf_counter()
    yh_vec = p.predict_many([b for b, _ in samples])
    t_vec = time.perf_counter() - t0
    assert np.allclose(yh_loop, yh_vec), "vectorized predict diverged"

    t0 = time.perf_counter()
    for b, y in samples[: n // 4]:
        p.observe(b, y)
    observe_us = (time.perf_counter() - t0) / (n // 4) * 1e6

    emit("predictor/microbench/predict_loop_ms", f"{t_loop * 1e3:.1f}",
         f"{n} samples, per-sample predict()")
    emit("predictor/microbench/predict_vec_ms", f"{t_vec * 1e3:.1f}",
         f"{n} samples, predict_many()")
    emit("predictor/microbench/observe_us", f"{observe_us:.1f}",
         "per online observation")
    assert t_vec < t_loop, (
        f"vectorized evaluate path lost to the loop: {t_vec:.4f}s >= "
        f"{t_loop:.4f}s")
    assert observe_us < 1000.0, (
        f"observe() costs {observe_us:.0f}us/sample — predictor overhead is "
        f"back in the hot loop")
    return {"predict_loop_s": t_loop, "predict_vec_s": t_vec,
            "observe_us": observe_us}


if __name__ == "__main__":
    main()
