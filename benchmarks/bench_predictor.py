"""Paper Table 5: Batch Latency Predictor fidelity (MAE / RMSE / R^2).

The paper evaluates on three GPU configs; we evaluate against the analytic
ground-truth executor for three TPU v5e model-parallel configurations.
Training follows the paper's protocol: offline init on profiled batches, then
online incremental updates from a real serving trace; evaluation is on a
held-out trace slice.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import QUICK, emit
from repro.configs.bench_models import BENCH_MODELS
from repro.core.predictor import BatchLatencyPredictor
from repro.core import SlidingServeScheduler
from repro.serving.costmodel import CostModel, HardwareSpec, ModelProfile
from repro.serving.simulator import ServingSimulator
from repro.serving.workloads import WorkloadSpec, make_workload

CONFIGS = [("v5e-tp1", 1), ("v5e-tp4", 4), ("v5e-tp8", 8)]


def trace_samples(chips: int, duration: float, qps_scale: float, seed: int = 9):
    """Harvest (batch, noisy latency, clean latency) from a live simulation."""
    cfg = BENCH_MODELS["qwen2.5-7b"]
    prof = ModelProfile.from_config(cfg)
    cm = CostModel(prof, HardwareSpec(chips=chips), seed=seed)
    wl = make_workload(WorkloadSpec("mixed-v1", 2.5 * qps_scale, duration, seed=seed), cm)
    sched = SlidingServeScheduler(max_budget=4096)
    samples = []
    orig = sched.observe
    def spy(batch, latency):
        samples.append((list(batch), latency, cm.latency(batch, noisy=False)))
        orig(batch, latency)
    sched.observe = spy
    ServingSimulator(sched, cm, wl, kv_capacity_tokens=512 * 1024).run()
    return samples


def main(quick: bool = QUICK) -> dict:
    duration = 60.0 if quick else 180.0
    results = {}
    for name, chips in CONFIGS:
        samples = trace_samples(chips, duration, qps_scale=max(1.0, chips * 0.75))
        if len(samples) < 200:
            samples = trace_samples(chips, duration * 2, qps_scale=max(1.0, chips))
        split = int(0.7 * len(samples))
        train, test = samples[:split], samples[split:]
        p = BatchLatencyPredictor()
        p.fit_offline([(b, y) for b, y, _ in train[: len(train) // 2]])
        for batch, y, _ in train[len(train) // 2:]:
            p.observe(batch, y)      # online incremental phase
        ev = p.evaluate([(b, y) for b, y, _ in test])
        # fidelity vs the *mean* latency: strips the irreducible runtime
        # jitter (the paper's GPUs traces have far larger between-batch
        # variance, so their R^2 vs raw runtimes is not noise-limited)
        ev_clean = p.evaluate([(b, yc) for b, _, yc in test])
        results[name] = {**ev, "r2_clean": ev_clean["r2"]}
        emit(f"predictor/{name}/mae_ms", f"{ev['mae'] * 1e3:.3f}", "paper: 2.5-2.7ms")
        emit(f"predictor/{name}/rmse_ms", f"{ev['rmse'] * 1e3:.3f}", "paper: 4.1-4.3ms")
        emit(f"predictor/{name}/r2", f"{ev['r2']:.4f}", "vs noisy runtimes")
        emit(f"predictor/{name}/r2_clean", f"{ev_clean['r2']:.4f}", "paper: >0.99")
        emit(f"predictor/{name}/n_test", ev["n"], "")
    return results


if __name__ == "__main__":
    main()
