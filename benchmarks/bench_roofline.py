"""Roofline table from the extrapolated probe measurements.

Reads the probe JSONL produced by the dry-run roofline pass (two-point layer
extrapolation, see `repro/analysis/extrapolate.py`), computes the three
roofline terms per (arch x shape) on the single-pod mesh, and emits both CSV
rows and the EXPERIMENTS.md markdown table.

Also measures per-round paged-attention time for the old split KV layout vs
the fused head-interleaved layout at three decode batch shapes (KV write +
attention, the whole per-layer round contribution) and records the A/B into
``BENCH_microkernels.json`` — the layout win is measured, not asserted.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import emit, write_bench_json
from repro.analysis.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, model_flops_estimate
from repro.configs import get_config
from repro.configs.base import SHAPES

N_DEV = 256
PROBES = os.environ.get("PROBES_JSONL", "results/probes.jsonl")


def load_rows(path: str = PROBES):
    rows = []
    if not os.path.exists(path):
        return rows
    for line in open(path):
        d = json.loads(line)
        if "error" in d:
            continue
        rows.append(d)
    return rows


def term_row(d: dict) -> dict:
    cfg = get_config(d["arch"])
    shape = SHAPES[d["shape"]]
    t_comp = d["flops"] / PEAK_FLOPS
    t_mem = d["bytes"] / HBM_BW
    t_coll = d["coll"] / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_estimate(cfg, shape)
    useful = mf / (d["flops"] * N_DEV) if d["flops"] else 0.0
    ideal = mf / (N_DEV * PEAK_FLOPS)
    frac = ideal / max(terms.values()) if max(terms.values()) > 0 else 0.0
    frac_comp = ideal / t_comp if t_comp > 0 else 0.0
    return dict(d, t_comp=t_comp, t_mem=t_mem, t_coll=t_coll,
                bottleneck=bottleneck, model_flops=mf, useful=useful,
                peak_fraction=frac, compute_bound_fraction=frac_comp)


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | t_comp ms | t_mem* ms | t_coll ms | bottleneck | "
           "useful 6ND/HLO | roofline frac | compute-bound frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    order = {a: i for i, a in enumerate(
        ["xlstm-125m", "jamba-1.5-large-398b", "llama3.2-3b", "gemma2-27b",
         "qwen3-1.7b", "gemma2-2b", "internvl2-26b", "qwen2-moe-a2.7b",
         "deepseek-v3-671b", "seamless-m4t-large-v2"])}
    shp = {s: i for i, s in enumerate(["train_4k", "prefill_32k", "decode_32k",
                                       "long_500k"])}
    lines = []
    for r in sorted(rows, key=lambda r: (order.get(r["arch"], 99),
                                         shp.get(r["shape"], 9))):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_comp'] * 1e3:.2f} | "
            f"{r['t_mem'] * 1e3:.2f} | {r['t_coll'] * 1e3:.3f} | "
            f"{r['bottleneck']} | {r['useful']:.2f} | "
            f"{r['peak_fraction']:.3f} | {r['compute_bound_fraction']:.3f} |")
    return hdr + "\n".join(lines) + "\n"


# decode batch shapes for the layout A/B: (batch, pages_per_seq) — a light
# interactive round, a steady mixed round, and a saturated decode round.
AB_SHAPES = [(8, 8), (32, 16), (128, 16)]


def attention_layout_ab() -> None:
    """Per-round attention time, old split pools vs fused head-interleaved
    pool, at three decode batch shapes. One 'round' = scatter the new KV
    (write_pages x2 vs write_pages_fused x1) + attend over the block tables
    (the split-pool oracle vs the fused dispatch `paged_attention_auto`
    takes — the Pallas kernels on TPU, the jnp oracles on CPU)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.paged_attention.ops import paged_attention_auto
    from repro.kernels.paged_attention.ref import paged_attention_ref
    from repro.models.attention import write_pages, write_pages_fused

    rng = np.random.default_rng(5)
    Hkv, G, D, ps = 4, 2, 64, 16
    section = {"backend": jax.default_backend(),
               "shape_fields": "(batch, pages_per_seq)"}
    for B, n in AB_SHAPES:
        P = max(B * n // 2, n + 1)          # half-utilized shared pool
        kp = jnp.asarray(rng.normal(size=(Hkv, P, ps, D)), jnp.bfloat16)
        vp = jnp.asarray(rng.normal(size=(Hkv, P, ps, D)), jnp.bfloat16)
        kvp = jnp.stack([kp, vp], axis=2)
        bt = jnp.asarray(rng.integers(0, P, (B, n)), jnp.int32)
        ln = jnp.asarray(rng.integers(ps, n * ps + 1, (B,)), jnp.int32)
        q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)), jnp.bfloat16)
        k_new = jnp.asarray(rng.normal(size=(B, 1, Hkv, D)), jnp.bfloat16)
        v_new = jnp.asarray(rng.normal(size=(B, 1, Hkv, D)), jnp.bfloat16)
        slots = jnp.asarray(rng.choice(P * ps, size=B, replace=False),
                            jnp.int32)

        def round_split():
            kp2 = write_pages(kp, k_new, slots)
            vp2 = write_pages(vp, v_new, slots)
            return paged_attention_ref(q, kp2, vp2, bt, ln, scale=D ** -0.5)

        def round_fused():
            kvp2 = write_pages_fused(kvp, k_new, v_new, slots)
            return paged_attention_auto(q, kvp2, bt, ln, scale=D ** -0.5)

        f_a, f_b = jax.jit(round_split), jax.jit(round_fused)

        def t(f, reps=10):
            jax.block_until_ready(f())
            t0 = time.perf_counter()
            for _ in range(reps):
                out = f()
            jax.block_until_ready(out)
            return (time.perf_counter() - t0) / reps * 1e6

        us_a, us_b = t(f_a), t(f_b)
        section[f"B{B}_n{n}"] = {"us_round_split": us_a,
                                 "us_round_fused": us_b,
                                 "speedup": us_a / us_b if us_b else 0.0}
        emit(f"roofline/attention_ab/B{B}_n{n}",
             f"{us_b:.0f}us fused", f"split {us_a:.0f}us")
    write_bench_json("layout_ab", section)


def main() -> None:
    attention_layout_ab()
    rows = [term_row(d) for d in load_rows()]
    if not rows:
        emit("roofline/status", "no probe data",
             f"run the dry-run roofline pass first ({PROBES})")
        return
    for r in rows:
        emit(f"roofline/{r['arch']}/{r['shape']}/bottleneck", r["bottleneck"],
             f"frac={r['peak_fraction']:.3f}")
    md = markdown_table(rows)
    out = os.environ.get("ROOFLINE_MD_OUT")
    if out:
        with open(out, "w") as f:
            f.write(md)
    print(md)


if __name__ == "__main__":
    main()
