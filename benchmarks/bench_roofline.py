"""Roofline table from the extrapolated probe measurements.

Reads the probe JSONL produced by the dry-run roofline pass (two-point layer
extrapolation, see `repro/analysis/extrapolate.py`), computes the three
roofline terms per (arch x shape) on the single-pod mesh, and emits both CSV
rows and the EXPERIMENTS.md markdown table.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit
from repro.analysis.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, model_flops_estimate
from repro.configs import get_config
from repro.configs.base import SHAPES

N_DEV = 256
PROBES = os.environ.get("PROBES_JSONL", "results/probes.jsonl")


def load_rows(path: str = PROBES):
    rows = []
    if not os.path.exists(path):
        return rows
    for line in open(path):
        d = json.loads(line)
        if "error" in d:
            continue
        rows.append(d)
    return rows


def term_row(d: dict) -> dict:
    cfg = get_config(d["arch"])
    shape = SHAPES[d["shape"]]
    t_comp = d["flops"] / PEAK_FLOPS
    t_mem = d["bytes"] / HBM_BW
    t_coll = d["coll"] / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_estimate(cfg, shape)
    useful = mf / (d["flops"] * N_DEV) if d["flops"] else 0.0
    ideal = mf / (N_DEV * PEAK_FLOPS)
    frac = ideal / max(terms.values()) if max(terms.values()) > 0 else 0.0
    frac_comp = ideal / t_comp if t_comp > 0 else 0.0
    return dict(d, t_comp=t_comp, t_mem=t_mem, t_coll=t_coll,
                bottleneck=bottleneck, model_flops=mf, useful=useful,
                peak_fraction=frac, compute_bound_fraction=frac_comp)


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | t_comp ms | t_mem* ms | t_coll ms | bottleneck | "
           "useful 6ND/HLO | roofline frac | compute-bound frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    order = {a: i for i, a in enumerate(
        ["xlstm-125m", "jamba-1.5-large-398b", "llama3.2-3b", "gemma2-27b",
         "qwen3-1.7b", "gemma2-2b", "internvl2-26b", "qwen2-moe-a2.7b",
         "deepseek-v3-671b", "seamless-m4t-large-v2"])}
    shp = {s: i for i, s in enumerate(["train_4k", "prefill_32k", "decode_32k",
                                       "long_500k"])}
    lines = []
    for r in sorted(rows, key=lambda r: (order.get(r["arch"], 99),
                                         shp.get(r["shape"], 9))):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_comp'] * 1e3:.2f} | "
            f"{r['t_mem'] * 1e3:.2f} | {r['t_coll'] * 1e3:.3f} | "
            f"{r['bottleneck']} | {r['useful']:.2f} | "
            f"{r['peak_fraction']:.3f} | {r['compute_bound_fraction']:.3f} |")
    return hdr + "\n".join(lines) + "\n"


def main() -> None:
    rows = [term_row(d) for d in load_rows()]
    if not rows:
        emit("roofline/status", "no probe data",
             f"run the dry-run roofline pass first ({PROBES})")
        return
    for r in rows:
        emit(f"roofline/{r['arch']}/{r['shape']}/bottleneck", r["bottleneck"],
             f"frac={r['peak_fraction']:.3f}")
    md = markdown_table(rows)
    out = os.environ.get("ROOFLINE_MD_OUT")
    if out:
        with open(out, "w") as f:
            f.write(md)
    print(md)


if __name__ == "__main__":
    main()
