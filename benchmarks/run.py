"""Benchmark orchestrator: one bench per paper table/figure + roofline.

CSV rows ``name,value,derived`` on stdout. Default is a quick pass; set
``BENCH_FULL=1`` for the full sweep used in EXPERIMENTS.md.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (bench_ablation, bench_goodput, bench_overload,
                            bench_predictor, bench_transient)
    benches = [
        ("goodput (Fig. 4)", bench_goodput.main),
        ("overload (Fig. 5)", bench_overload.main),
        ("transient (Fig. 6)", bench_transient.main),
        ("ablation (Table 4)", bench_ablation.main),
        ("predictor (Table 5)", bench_predictor.main),
    ]
    failures = 0
    for name, fn in benches:
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    for name, modname in [("kernel microbenches", "bench_microkernels"),
                          ("roofline table", "bench_roofline")]:
        try:
            import importlib
            mod = importlib.import_module(f"benchmarks.{modname}")
            print(f"# --- {name} ---", flush=True)
            mod.main()
        except ImportError:
            pass
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
