"""Shared benchmark plumbing: scheduler registry, simulation runner, CSV."""
from __future__ import annotations

import os
import time
from typing import Dict, Optional

from repro.configs.bench_models import BENCH_MODELS
from repro.core import (
    FCFSStaticScheduler, QoServeLikeScheduler, SarathiEDFScheduler,
    SingleStepGreedyScheduler, SlidingServeScheduler,
)
from repro.serving.costmodel import CostModel, HardwareSpec, ModelProfile
from repro.serving.metrics import summarize
from repro.serving.simulator import ServingSimulator
from repro.serving.workloads import WorkloadSpec, make_workload

SCHEDULERS = {
    "sarathi-edf": SarathiEDFScheduler,
    "single-step": SingleStepGreedyScheduler,
    "qoserve": QoServeLikeScheduler,
    "slidingserve": SlidingServeScheduler,
}

QUICK = os.environ.get("BENCH_FULL", "0") != "1"


def hw_for(model_name: str, chips: int = 1) -> HardwareSpec:
    return HardwareSpec(chips=chips)


def run_sim(sched_name: str, model_name: str, dataset: str, qps: float,
            duration: float, seed: int = 3, kv_tokens: int = 512 * 1024,
            sched_kwargs: Optional[Dict] = None, collect_trace: bool = False):
    cfg = BENCH_MODELS[model_name]
    prof = ModelProfile.from_config(cfg)
    cm = CostModel(prof, hw_for(model_name), seed=7)
    wl = make_workload(WorkloadSpec(dataset, qps, duration, seed=seed), cm)
    sched = SCHEDULERS[sched_name](max_budget=4096, **(sched_kwargs or {}))
    sim = ServingSimulator(sched, cm, wl, kv_capacity_tokens=kv_tokens,
                           collect_trace=collect_trace)
    res = sim.run()
    return res, summarize(res.requests, res.duration)


def emit(name: str, value, derived: str = "") -> None:
    """CSV row: name,value,derived."""
    print(f"{name},{value},{derived}")
