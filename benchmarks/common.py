"""Shared benchmark plumbing: scheduler registry, simulation runner, CSV,
and the merged BENCH_microkernels.json artifact writer."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Optional

from repro.configs.bench_models import BENCH_MODELS
from repro.core import (
    FCFSStaticScheduler, QoServeLikeScheduler, SarathiEDFScheduler,
    SingleStepGreedyScheduler, SlidingServeScheduler,
)
from repro.serving.costmodel import CostModel, HardwareSpec, ModelProfile
from repro.serving.metrics import summarize
from repro.serving.simulator import ServingSimulator
from repro.serving.workloads import WorkloadSpec, make_workload

SCHEDULERS = {
    "sarathi-edf": SarathiEDFScheduler,
    "single-step": SingleStepGreedyScheduler,
    "qoserve": QoServeLikeScheduler,
    "slidingserve": SlidingServeScheduler,
}

QUICK = os.environ.get("BENCH_FULL", "0") != "1"


def hw_for(model_name: str, chips: int = 1) -> HardwareSpec:
    return HardwareSpec(chips=chips)


def run_sim(sched_name: str, model_name: str, dataset: str, qps: float,
            duration: float, seed: int = 3, kv_tokens: int = 512 * 1024,
            sched_kwargs: Optional[Dict] = None, collect_trace: bool = False,
            sim_kwargs: Optional[Dict] = None):
    cfg = BENCH_MODELS[model_name]
    prof = ModelProfile.from_config(cfg)
    cm = CostModel(prof, hw_for(model_name), seed=7)
    wl = make_workload(WorkloadSpec(dataset, qps, duration, seed=seed), cm)
    sched = SCHEDULERS[sched_name](max_budget=4096, **(sched_kwargs or {}))
    sim = ServingSimulator(sched, cm, wl, kv_capacity_tokens=kv_tokens,
                           collect_trace=collect_trace, **(sim_kwargs or {}))
    res = sim.run()
    return res, summarize(res.requests, res.duration)


def emit(name: str, value, derived: str = "") -> None:
    """CSV row: name,value,derived."""
    print(f"{name},{value},{derived}")


MICROKERNEL_JSON = os.environ.get("BENCH_MICROKERNELS_JSON",
                                  "BENCH_microkernels.json")


def write_bench_json(section: str, payload: Dict,
                     path: str = MICROKERNEL_JSON) -> None:
    """Merge ``payload`` under ``section`` into the shared kernel-bench JSON
    artifact. Sections are written independently (``--dma-overlap`` and the
    roofline layout A/B run as separate CI steps) so each rewrite preserves
    the others' numbers."""
    data: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
    data[section] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    emit(f"bench_json/{section}", path, f"{len(payload)} entries")
