"""Paper Fig. 4: maximum goodput under SLO constraints.

Goodput = requests/s served with <= 1% of requests violating their SLO
(p99-style cap); the maximum is found by QPS binary search per
(model x dataset x scheduler).

``--engine`` additionally runs the *real-execution* engine comparison (slot
cache vs paged KV on a reduced config): same workload, identical prompts;
reports concurrency ceiling, JIT dispatches per scheduler round, and wall
time. The paged engine must admit more concurrent requests than
``max_slots`` and spend <= 2 model calls per round regardless of how many
prefill requests a decision names.
"""
from __future__ import annotations

import sys

from benchmarks.common import QUICK, SCHEDULERS, emit, run_sim
from repro.serving.metrics import max_goodput

SEARCH = {
    # dataset: (lo, hi) QPS search bracket
    "sharegpt": (0.5, 16.0),
    "arxiv-v1": (0.25, 4.0),
    "arxiv-v2": (0.25, 3.0),
    "mixed-v1": (0.125, 8.0),
    "mixed-v2": (0.125, 8.0),
}


def main(quick: bool = QUICK) -> dict:
    models = ["qwen2.5-7b"] if quick else ["qwen2.5-7b", "llama3-8b"]
    datasets = ["sharegpt", "arxiv-v1", "mixed-v1"] if quick else list(SEARCH)
    duration = 60.0 if quick else 150.0
    iters = 5 if quick else 7
    results = {}
    for model in models:
        for ds in datasets:
            lo, hi = SEARCH[ds]
            base = None
            for sched in SCHEDULERS:
                def at(qps, _s=sched):
                    _, summ = run_sim(_s, model, ds, qps, duration)
                    return summ
                out = max_goodput(at, lo, hi, violation_cap=0.01, iters=iters)
                results[(model, ds, sched)] = out["qps"]
                emit(f"goodput/{model}/{ds}/{sched}", f"{out['qps']:.3f}",
                     f"viol={out['summary']['violation_rate']:.4f}")
                if sched == "sarathi-edf":
                    base = out["qps"]
                elif sched == "slidingserve" and base:
                    gain = (results[(model, ds, "slidingserve")] / max(base, 1e-9) - 1) * 100
                    emit(f"goodput_gain_vs_sarathi/{model}/{ds}", f"{gain:.1f}%",
                         "paper claims 25-111%")
    return results


def engine_comparison(n_requests: int = 12, seed: int = 0) -> dict:
    """Slot vs paged ServingEngine on a reduced config with real forwards."""
    import numpy as np
    from repro.configs import get_config
    from repro.core import SlidingServeScheduler
    from repro.serving.engine import ServingEngine
    from repro.serving.request import Request

    cfg = get_config("llama3.2-3b").smoke()
    rng = np.random.default_rng(seed)
    proto = [Request(rid=i, arrival=0.0,
                     prompt_len=int(rng.integers(16, 96)),
                     max_output=int(rng.integers(3, 6)),
                     ttft_slo=60.0, tbt_slo=60.0) for i in range(n_requests)]
    prompts = {r.rid: rng.integers(1, cfg.vocab_size, r.prompt_len).astype(np.int32)
               for r in proto}
    results = {}
    for mode in ("slot", "paged"):
        reqs = [Request(rid=r.rid, arrival=r.arrival, prompt_len=r.prompt_len,
                        max_output=r.max_output, ttft_slo=r.ttft_slo,
                        tbt_slo=r.tbt_slo) for r in proto]
        sched = SlidingServeScheduler(max_budget=512, max_iter_time=5.0)
        eng = ServingEngine(cfg, sched, cache_mode=mode, max_slots=8,
                            max_len=256, kv_capacity_tokens=4096)
        out = eng.serve(reqs, {k: v.copy() for k, v in prompts.items()},
                        max_wall_s=600.0)
        st = out["stats"]
        calls_per_round = ((st.prefill_calls + st.decode_calls)
                           / max(st.iterations, 1))
        results[mode] = {"finished": len(out["finished"]),
                         "max_concurrency": st.max_concurrency,
                         "calls_per_round": calls_per_round,
                         "max_round_calls": st.max_round_calls,
                         "wall": out["wall"]}
        emit(f"engine/{mode}/finished", len(out["finished"]), f"of {n_requests}")
        emit(f"engine/{mode}/max_concurrency", st.max_concurrency,
             "slot ceiling is max_slots=8" if mode == "slot" else
             "paged: bounded by KV pages only")
        emit(f"engine/{mode}/calls_per_round", f"{calls_per_round:.2f}",
             "paged fuses all prefill rows into one dispatch"
             if mode == "paged" else "slot pays one dispatch per prefill req")
        emit(f"engine/{mode}/wall_s", f"{out['wall']:.1f}", "")
    return results


if __name__ == "__main__":
    if "--engine" in sys.argv:
        engine_comparison()
    else:
        main()
