"""Paper Fig. 4: maximum goodput under SLO constraints.

Goodput = requests/s served with <= 1% of requests violating their SLO
(p99-style cap); the maximum is found by QPS binary search per
(model x dataset x scheduler).

Note on ``sarathi-edf``: its static chunk is TBT-calibrated (see
``core/baselines.py``) — the earlier hardcoded 512-token chunk overshot the
dialogue TBT every mixed round and collapsed its measured goodput to the
search bracket's floor. With the calibrated baseline, SlidingServe's edge
concentrates where the paper's claims live: long-prompt and saturating/
overload regimes (see tests/test_integration_paper.py), not light load.

``--engine`` additionally runs the *real-execution* engine comparison (slot
cache vs paged KV on a reduced config), driven through the streaming
``InferenceServer`` + open-loop live-arrival path (the online API): same
workload, identical prompts; reports concurrency ceiling, JIT dispatches
per scheduler round, readbacks per round, and wall time. The paged engine must admit more concurrent requests than
``max_slots`` and spend <= 2 model calls per round no matter how many
prefill requests a decision names (for rounds within the ROW_BUCKETS row
ladder; larger rounds add one dispatch per extra row group).

``--profile-overhead`` serves one workload through the real paged engine
twice — zero-sync overlapped pipeline vs the legacy sync-every-row hot path
(``overlap=False``) — and reports rounds/sec, host-overhead fraction and
device readback counts for both.

``--spec-k K`` serves one periodic workload through the real paged engine
twice — n-gram speculative decoding at K vs plain decode at 0 — and records
acceptance rate, tokens per verify row, rounds saved and the goodput delta
(greedy outputs must match bitwise).

Every entry point appends its results to ``BENCH_goodput.json`` (cwd), the
machine-readable perf-trajectory record CI uploads per run.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys

from benchmarks.common import QUICK, SCHEDULERS, emit, run_sim
from repro.serving.metrics import max_goodput

JSON_PATH = os.environ.get("BENCH_GOODPUT_JSON", "BENCH_goodput.json")


def write_json(section: str, payload: dict) -> None:
    """Merge ``payload`` under ``section`` in the trajectory JSON."""
    doc = {"schema": 1}
    if os.path.exists(JSON_PATH):
        try:
            with open(JSON_PATH) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            pass
    doc["quick"] = QUICK
    doc[section] = payload
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    emit(f"json/{section}", JSON_PATH, "machine-readable trajectory record")

SEARCH = {
    # dataset: (lo, hi) QPS search bracket
    "sharegpt": (0.5, 16.0),
    "arxiv-v1": (0.25, 4.0),
    "arxiv-v2": (0.25, 3.0),
    "mixed-v1": (0.125, 8.0),
    "mixed-v2": (0.125, 8.0),
}


def main(quick: bool = QUICK) -> dict:
    models = ["qwen2.5-7b"] if quick else ["qwen2.5-7b", "llama3-8b"]
    datasets = ["sharegpt", "arxiv-v1", "mixed-v1"] if quick else list(SEARCH)
    duration = 60.0 if quick else 150.0
    iters = 5 if quick else 7
    results = {}
    record = {}
    for model in models:
        for ds in datasets:
            lo, hi = SEARCH[ds]
            base = None
            for sched in SCHEDULERS:
                def at(qps, _s=sched):
                    _, summ = run_sim(_s, model, ds, qps, duration)
                    return summ
                out = max_goodput(at, lo, hi, violation_cap=0.01, iters=iters)
                results[(model, ds, sched)] = out["qps"]
                record[f"{model}/{ds}/{sched}"] = {
                    "goodput_qps": out["qps"],
                    "violation_rate": out["summary"]["violation_rate"],
                }
                emit(f"goodput/{model}/{ds}/{sched}", f"{out['qps']:.3f}",
                     f"viol={out['summary']['violation_rate']:.4f}")
                if sched == "sarathi-edf":
                    base = out["qps"]
                elif sched == "slidingserve" and base:
                    gain = (results[(model, ds, "slidingserve")] / max(base, 1e-9) - 1) * 100
                    emit(f"goodput_gain_vs_sarathi/{model}/{ds}", f"{gain:.1f}%",
                         "paper claims 25-111%")
    write_json("goodput", record)
    return results


def engine_comparison(n_requests: int = 12, seed: int = 0) -> dict:
    """Slot vs paged engine on a reduced config with real forwards, driven
    through the *online* API: an InferenceServer submits every request to
    the step-based EngineCore via the open-loop live-arrival driver (the
    streaming production path), not the offline ``serve()`` wrapper.

    The paged engine honors the shared mesh override (``REPRO_FORCE_MESH``,
    e.g. the CI forced-host-mesh job): the record then carries the mesh
    shape + per-shard KV accounting, and greedy behavior must be identical.
    Requests rotate through the named SLO classes so the per-class
    violation/goodput breakdown in BENCH_goodput.json is populated."""
    import numpy as np
    from repro.configs import get_config
    from repro.core import SlidingServeScheduler
    from repro.launch.mesh import make_serving_mesh
    from repro.serving.engine import EngineCore
    from repro.serving.metrics import summarize_by_class
    from repro.serving.request import Request
    from repro.serving.server import SLO_CLASSES, InferenceServer
    from repro.serving.workloads import run_open_loop

    cfg = get_config("llama3.2-3b").smoke()
    rng = np.random.default_rng(seed)
    classes = sorted(SLO_CLASSES)
    proto = [Request(rid=i, arrival=0.0,
                     prompt_len=int(rng.integers(16, 96)),
                     max_output=int(rng.integers(3, 6)),
                     ttft_slo=60.0, tbt_slo=60.0,
                     slo_class=classes[i % len(classes)])
             for i in range(n_requests)]
    prompts = {r.rid: rng.integers(1, cfg.vocab_size, r.prompt_len).astype(np.int32)
               for r in proto}
    results = {}
    for mode in ("slot", "paged"):
        reqs = [Request(rid=r.rid, arrival=r.arrival, prompt_len=r.prompt_len,
                        max_output=r.max_output, ttft_slo=r.ttft_slo,
                        tbt_slo=r.tbt_slo, slo_class=r.slo_class)
                for r in proto]
        sched = SlidingServeScheduler(max_budget=512, max_iter_time=5.0)
        mesh = make_serving_mesh(None) if mode == "paged" else None
        core = EngineCore(cfg, sched, cache_mode=mode, max_slots=8,
                          max_len=256, kv_capacity_tokens=4096, mesh=mesh)
        server = InferenceServer(core)
        out = run_open_loop(server, reqs,
                            {k: v.copy() for k, v in prompts.items()},
                            max_wall_s=600.0)
        st = core.stats
        calls_per_round = ((st.prefill_calls + st.decode_calls)
                           / max(st.iterations, 1))
        results[mode] = {"finished": len(out["finished"]),
                         "max_concurrency": st.max_concurrency,
                         "calls_per_round": calls_per_round,
                         "max_round_calls": st.max_round_calls,
                         "wall": out["wall"],
                         "finished_by_class": dict(st.finished_by_class),
                         "evicted_by_class": dict(st.evicted_by_class),
                         "per_class": summarize_by_class(reqs, out["wall"])}
        if mode == "paged":
            results[mode]["sharding"] = core.shard_info()
            results[mode]["prefix_cache"] = core.cache_info()
            if mesh is not None:
                emit("engine/paged/mesh", results[mode]["sharding"]["mesh"],
                     f"kv_partition={results[mode]['sharding']['kv_partition']}")
        emit(f"engine/{mode}/finished", len(out["finished"]), f"of {n_requests}")
        emit(f"engine/{mode}/max_concurrency", st.max_concurrency,
             "slot ceiling is max_slots=8" if mode == "slot" else
             "paged: bounded by KV pages only")
        emit(f"engine/{mode}/calls_per_round", f"{calls_per_round:.2f}",
             "paged fuses all prefill rows into one dispatch"
             if mode == "paged" else "slot pays one dispatch per prefill req")
        emit(f"engine/{mode}/wall_s", f"{out['wall']:.1f}", "")
        if mode == "paged":
            emit("engine/paged/readbacks_per_round",
                 f"{st.token_readbacks / max(st.iterations, 1):.2f}",
                 "1.0 = zero-sync preserved under the streaming frontend")
    write_json("engine_comparison", results)
    return results


def profile_overhead(n_requests: int = 12, max_output: int = 32,
                     seed: int = 0, repeats: int = 5) -> dict:
    """Zero-sync hot-path A/B on the real paged engine: the overlapped
    one-readback-per-round pipeline vs the legacy sync-every-row loop
    (``overlap=False``), identical workload and prompts. Reports rounds/sec,
    host-overhead fraction (host time / wall), SLO-violation rate and
    device readback counts for both; greedy outputs must match exactly.
    Each mode is JIT-warmed and then measured ``repeats`` times (best pass
    reported — CI boxes are contended and single passes are noisy)."""
    import numpy as np
    from repro.configs import get_config
    from repro.core import SlidingServeScheduler
    from repro.serving.engine import ServingEngine
    from repro.serving.request import Request

    cfg = get_config("llama3.2-3b").smoke()
    rng = np.random.default_rng(seed)
    proto = [Request(rid=i, arrival=0.0,
                     prompt_len=int(rng.integers(24, 96)),
                     max_output=max_output,
                     ttft_slo=30.0, tbt_slo=5.0) for i in range(n_requests)]
    prompts = {r.rid: rng.integers(1, cfg.vocab_size, r.prompt_len).astype(np.int32)
               for r in proto}
    results, outputs = {}, {}
    for label, overlap in (("overlap", True), ("sync_per_row", False)):
        from repro.serving.engine import EngineStats
        sched = SlidingServeScheduler(max_budget=512, max_iter_time=5.0)
        eng = ServingEngine(cfg, sched, cache_mode="paged",
                            kv_capacity_tokens=8192, overlap=overlap)
        # warmup pass (same shapes, shifted rids): JIT compilation must not
        # be attributed to either hot path — the A/B measures steady state.
        warm = [dataclasses.replace(r, rid=r.rid + 10_000) for r in proto]
        eng.serve(warm, {r.rid: prompts[r.rid - 10_000].copy() for r in warm},
                  max_wall_s=600.0)
        best = None
        for rep in range(repeats):
            off = rep * 20_000
            eng.stats = EngineStats()
            reqs = [dataclasses.replace(r, rid=r.rid + off) for r in proto]
            out = eng.serve(reqs, {r.rid: prompts[r.rid - off].copy()
                                   for r in reqs}, max_wall_s=600.0)
            if rep == 0:
                outputs[label] = {rid: toks for rid, toks
                                  in out["outputs"].items() if rid < 10_000}
            if best is None or out["wall"] < best[0]["wall"]:
                best = (out, reqs)
        out, reqs = best
        st = out["stats"]
        wall = max(out["wall"], 1e-9)
        viol = sum(r.violations()["violated"] for r in reqs) / len(reqs)
        results[label] = {
            "finished": len(out["finished"]),
            "wall_s": wall,
            "rounds_per_s": st.iterations / wall,
            "host_overhead_fraction": st.host_s / wall,
            "sync_s": st.sync_s,
            "dispatch_s": st.dispatch_s,
            "token_readbacks": st.token_readbacks,
            "readbacks_per_round": st.token_readbacks / max(st.iterations, 1),
            "reused_table_uploads": st.reused_uploads,
            "slo_violation_rate": viol,
        }
        emit(f"profile/{label}/rounds_per_s",
             f"{results[label]['rounds_per_s']:.2f}", "")
        emit(f"profile/{label}/host_overhead_fraction",
             f"{results[label]['host_overhead_fraction']:.3f}",
             "host time / wall")
        emit(f"profile/{label}/readbacks_per_round",
             f"{results[label]['readbacks_per_round']:.2f}",
             "1.0 = zero-sync target" if overlap else "legacy per-row syncs")
    assert outputs["overlap"] == outputs["sync_per_row"], \
        "overlapped pipeline changed greedy outputs"
    results["speedup_rounds_per_s"] = (results["overlap"]["rounds_per_s"]
                                       / results["sync_per_row"]["rounds_per_s"])
    emit("profile/speedup_rounds_per_s",
         f"{results['speedup_rounds_per_s']:.3f}", "overlap vs sync-per-row")
    write_json("profile_overhead", results)
    return results


def speculation_comparison(spec_k: int = 4, n_requests: int = 8,
                           max_output: int = 24, seed: int = 0,
                           repeats: int = 3) -> dict:
    """Speculative-decoding A/B on the real paged engine: the same periodic
    workload served at ``--spec-k K`` (n-gram drafting into multi-token
    verify rows) and at 0 (plain one-token decode). Records acceptance rate,
    emitted tokens per verify row, rounds, wall time and the goodput delta
    under ``speculation`` in ``BENCH_goodput.json``; greedy outputs must
    match bitwise — speculation changes the *schedule*, never the stream.
    Each mode is JIT-warmed and measured ``repeats`` times (best pass)."""
    import numpy as np
    from repro.configs import get_config
    from repro.core import SlidingServeScheduler
    from repro.serving.engine import EngineStats, ServingEngine
    from repro.serving.request import Request

    cfg = get_config("llama3.2-3b").smoke()
    rng = np.random.default_rng(seed)
    proto = [Request(rid=i, arrival=0.0, prompt_len=36,
                     max_output=max_output, ttft_slo=30.0, tbt_slo=5.0)
             for i in range(n_requests)]
    # periodic prompts: the prompt-lookup drafter's favorable regime (the
    # published speculation gains all assume a draftable token distribution)
    prompts = {r.rid: np.tile(rng.integers(1, cfg.vocab_size, 12),
                              3).astype(np.int32)
               for r in proto}
    results, outputs = {}, {}
    for label, k in (("spec", spec_k), ("baseline", 0)):
        sched = SlidingServeScheduler(max_budget=512, max_iter_time=5.0)
        eng = ServingEngine(cfg, sched, cache_mode="paged",
                            kv_capacity_tokens=8192, spec_k=k)
        warm = [dataclasses.replace(r, rid=r.rid + 10_000) for r in proto]
        eng.serve(warm, {r.rid: prompts[r.rid - 10_000].copy() for r in warm},
                  max_wall_s=600.0)
        best = None
        for rep in range(repeats):
            off = rep * 20_000
            eng.stats = EngineStats()
            reqs = [dataclasses.replace(r, rid=r.rid + off) for r in proto]
            out = eng.serve(reqs, {r.rid: prompts[r.rid - off].copy()
                                   for r in reqs}, max_wall_s=600.0)
            if rep == 0:
                outputs[label] = {rid % 20_000: toks for rid, toks
                                  in out["outputs"].items()}
            if best is None or out["wall"] < best["wall"]:
                best = out
        st = best["stats"]
        wall = max(best["wall"], 1e-9)
        results[label] = {
            "spec_k": k,
            "finished": len(best["finished"]),
            "wall_s": wall,
            "rounds": st.iterations,
            "goodput_rps": len(best["finished"]) / wall,
            "readbacks_per_round": st.token_readbacks / max(st.iterations, 1),
        }
        if k:
            results[label].update(eng.spec_info())
            emit(f"speculation/acceptance_rate",
                 f"{results[label]['acceptance_rate']:.3f}",
                 f"{results[label]['accepted_tokens']}"
                 f"/{results[label]['draft_tokens']} drafted tokens")
            emit(f"speculation/tokens_per_verify_row",
                 f"{results[label]['tokens_per_verify_row']:.2f}",
                 "> 1.0 = multi-token rounds are real")
        emit(f"speculation/{label}/rounds", st.iterations,
             f"wall={wall:.1f}s")
    assert outputs["spec"] == outputs["baseline"], \
        "speculation changed greedy outputs"
    spec, base = results["spec"], results["baseline"]
    assert spec["tokens_per_verify_row"] > 1.0, spec
    assert spec["readbacks_per_round"] == 1.0, \
        "speculation broke the one-readback-per-round property"
    results["token_parity"] = True
    results["rounds_saved"] = base["rounds"] - spec["rounds"]
    results["engine_goodput_delta_rps"] = (spec["goodput_rps"]
                                           - base["goodput_rps"])
    emit("speculation/rounds_saved", results["rounds_saved"],
         f"of {base['rounds']} baseline rounds")
    emit("speculation/engine_goodput_delta_rps",
         f"{results['engine_goodput_delta_rps']:.3f}",
         "CPU wall time; verify-row compute is not free on a host CPU")

    # goodput projection on the dialogue scenario at moderate load: decode
    # rows are memory-bound on the accelerator cost model, so (1 + k)-token
    # verify rows ride at decode-row cost while accepted tokens buy whole
    # rounds — the regime where speculation pays. The acceptance rate fed
    # into the simulator is the one *measured* on real forwards above.
    from benchmarks.common import run_sim
    acc = spec["acceptance_rate"]
    sim = {"acceptance_rate": acc, "dataset": "sharegpt", "qps": 4.0}
    for label, kw in (("spec", dict(spec_k=spec_k, spec_acceptance=acc)),
                      ("baseline", {})):
        _, summ = run_sim("slidingserve", "qwen2.5-7b", "sharegpt", 4.0,
                          60.0, sim_kwargs=kw)
        sim[label] = {"goodput_rps": summ["goodput_rps"],
                      "violation_rate": summ["violation_rate"],
                      "tbt_p99": summ.get("tbt_p99")}
    results["dialogue_sim"] = sim
    results["goodput_delta_rps"] = (sim["spec"]["goodput_rps"]
                                    - sim["baseline"]["goodput_rps"])
    assert results["goodput_delta_rps"] >= 0.0, results["dialogue_sim"]
    emit("speculation/goodput_delta_rps",
         f"{results['goodput_delta_rps']:.3f}",
         "dialogue scenario, moderate load (simulator, measured acceptance)")
    write_json("speculation", results)
    return results


def prefix_cache_comparison(n_requests: int = 8, seed: int = 0) -> dict:
    """Radix-prefix-cache A/B on the real paged engine: the shared-system-
    prompt scenario plus a multi-turn follow-up wave, served with the cache
    on and off (identical prompts, identical SLOs). Records cache hit rate,
    computed prefill tokens, wall time and the goodput delta into
    ``BENCH_goodput.json``; greedy outputs must match bitwise — the cache
    changes how much prefill runs, never what it computes."""
    import numpy as np
    from repro.configs import get_config
    from repro.core import SlidingServeScheduler
    from repro.serving.engine import EngineCore
    from repro.serving.metrics import summarize
    from repro.serving.server import InferenceServer
    from repro.serving.workloads import (make_shared_prefix_workload,
                                         multiturn_followup, run_open_loop)

    cfg = get_config("llama3.2-3b").smoke()
    results, outputs = {}, {}
    for label, enabled in (("cache_on", True), ("cache_off", False)):
        sched = SlidingServeScheduler(max_budget=512, max_iter_time=5.0)
        core = EngineCore(cfg, sched, cache_mode="paged",
                          kv_capacity_tokens=8192, prefix_cache=enabled)
        server = InferenceServer(core)
        reqs, prompts = make_shared_prefix_workload(
            n_requests, cfg.vocab_size, system_len=96, unique_len=32,
            max_output=6, qps=4.0, seed=seed)
        out = run_open_loop(server, reqs,
                            {k: v.copy() for k, v in prompts.items()},
                            max_wall_s=600.0)
        # multi-turn wave: each conversation's turn 2 re-submits its full
        # transcript plus a fresh user turn (matches frozen decode pages too)
        rng = np.random.default_rng(seed + 1)
        turn2 = {}
        for rid in sorted(out["handles"]):
            h = out["handles"][rid]
            p2 = multiturn_followup(prompts[rid], h.collected, rng,
                                    cfg.vocab_size, turn_len=24)
            turn2[rid] = server.submit(p2, slo_class="standard",
                                       max_output=4)
        server.run(max_wall_s=600.0)
        wall = core.now()
        ci = core.cache_info()
        fin = [h.request for h in out["handles"].values()] + \
              [h.request for h in turn2.values()]
        summ = summarize(fin, wall)
        outputs[label] = ({rid: h.collected for rid, h in out["handles"].items()},
                          {rid: h.collected for rid, h in turn2.items()})
        results[label] = {
            "finished": len([h for h in turn2.values() if h.finished]) +
                        len(out["finished"]),
            "wall_s": wall,
            "hit_rate": ci["hit_rate"],
            "hit_tokens": ci["hit_tokens"],
            "prompt_tokens": ci["prompt_tokens"],
            "prefill_tokens_computed": ci["prefill_tokens_computed"],
            "cache_commits": ci.get("cache_commits", 0),
            "goodput_rps": summ["goodput_rps"],
        }
        emit(f"prefix_cache/{label}/hit_rate", f"{ci['hit_rate']:.3f}", "")
        emit(f"prefix_cache/{label}/prefill_tokens",
             ci["prefill_tokens_computed"],
             f"of {ci['prompt_tokens']} prompt tokens admitted")
    assert outputs["cache_on"] == outputs["cache_off"], \
        "prefix cache changed greedy outputs"
    on, off = results["cache_on"], results["cache_off"]
    results["prefill_tokens_saved"] = (off["prefill_tokens_computed"]
                                       - on["prefill_tokens_computed"])
    results["goodput_delta_rps"] = on["goodput_rps"] - off["goodput_rps"]
    results["token_parity"] = True
    emit("prefix_cache/prefill_tokens_saved",
         results["prefill_tokens_saved"], "cache on vs off, same workload")
    emit("prefix_cache/goodput_delta_rps",
         f"{results['goodput_delta_rps']:.3f}", "")
    write_json("prefix_cache", results)
    return results


def router_comparison(replicas: int = 2, seed: int = 0) -> dict:
    """Multi-replica routing A/B on real engines: the shared-prefix +
    background-batch trace served three ways — one engine (the token-parity
    reference), ``replicas`` engines behind the **prefix-affine** router,
    and the same fleet behind cache-blind **round-robin**.

    Asserts (the router-smoke CI job's acceptance gates):
    * greedy tokens bit-identical across all three (replicas share seed=0
      params, so placement must never change outputs);
    * the affine run's directory hit rate > 50% (the shared stream lands on
      its holder);
    * the affine run's computed-token imbalance (max/min per-replica
      prefill+decode tokens) below round-robin's on the same trace.

    Records per-replica goodput, peak queue depth, computed tokens, the
    directory hit rate and the imbalance under ``router`` in
    ``BENCH_goodput.json``."""
    import numpy as np
    from repro.configs import get_config
    from repro.core import SlidingServeScheduler
    from repro.frontend.router import EngineRouter, LocalReplica
    from repro.serving.engine import EngineStats
    from repro.serving.server import InferenceServer
    from repro.serving.workloads import make_router_workload, run_open_loop

    cfg = get_config("llama3.2-3b").smoke()

    def mk_server():
        s = InferenceServer.build(
            cfg, scheduler=SlidingServeScheduler(max_budget=512,
                                                 max_iter_time=5.0),
            cache_mode="paged", kv_capacity_tokens=4096, page_size=16)
        # JIT warmup before the measured trace: compile the trace's prefill
        # buckets (400/120-token prompts) and the multi-row decode shapes a
        # concurrent burst reaches — cold compiles take seconds and would
        # swallow the arrival spacing the directory needs (commits must land
        # between arrivals for affinity to engage).
        rng = np.random.default_rng(7)
        for i, n in enumerate((400, 120, 120, 120)):
            s.submit(rng.integers(1, cfg.vocab_size, n).astype(np.int32),
                     max_output=4, rid=90_000 + i)
        s.run(max_wall_s=600.0)
        for i in range(4):
            s.release(90_000 + i)
        s.core.stats = EngineStats()
        return s

    # calibrate the arrival gap to this machine's engine speed. The gap must
    # sit in a window: above the commit latency (pages freeze at the end of
    # the prefill round, ~2 rounds — so the first shared request's pages are
    # in the directory before the second routes) but below the end-to-end
    # service time (so work overlaps and load-aware placement has load to
    # see — fully serial arrivals would leave every replica idle at every
    # placement). One warmed 120-token + 6-token request is ~7 rounds; a gap
    # of ~4 round-times lands in the window.
    rng = np.random.default_rng(11)
    cal = mk_server()
    import time as _time
    t0 = _time.perf_counter()
    cal.submit(rng.integers(1, cfg.vocab_size, 120).astype(np.int32),
               max_output=6, rid=90_100)
    cal.run(max_wall_s=600.0)
    gap_s = min(max(0.6 * (_time.perf_counter() - t0), 0.2), 3.0)
    emit("router/arrival_gap_s", f"{gap_s:.2f}",
         "~4 round-times (one warmed request is ~7 rounds)")

    def workload():
        # heavy_output=64: the heavy request must still be decoding while
        # the shared stream and the trailing batch arrive, or there is no
        # load for placement to balance against
        return make_router_workload(cfg.vocab_size, n_shared=12,
                                    heavy_output=64, gap_s=gap_s, seed=seed)

    results = {}
    outputs = {}

    # reference: one engine, same trace (and the single-replica goodput bar)
    reqs, prompts = workload()
    server = mk_server()
    out = run_open_loop(server, reqs,
                        {k: v.copy() for k, v in prompts.items()},
                        max_wall_s=600.0)
    outputs["single"] = {rid: list(h.collected)
                         for rid, h in out["handles"].items()}
    st = server.core.stats
    results["single"] = {
        "finished": len(out["finished"]),
        "wall_s": out["wall"],
        "goodput_rps": len(out["finished"]) / max(out["wall"], 1e-9),
        "computed_tokens": st.prefill_tokens + st.decode_tokens,
    }
    emit("router/single/finished", len(out["finished"]), f"of {len(reqs)}")

    for policy in ("prefix-affine", "round-robin"):
        key = policy.replace("-", "_")
        reqs, prompts = workload()
        router = EngineRouter([LocalReplica(i, mk_server())
                               for i in range(replicas)], policy=policy)
        out = router.run_open_loop(reqs, prompts, max_wall_s=600.0)
        outputs[key] = {rid: list(h.collected)
                        for rid, h in out["handles"].items()}
        per_replica = []
        computed = []
        for rep in router.replicas:
            st = rep.server.core.stats
            fin = sum(1 for rid, idx in router._owner.items()
                      if idx == rep.index
                      and out["handles"][rid].finished
                      and not out["handles"][rid].aborted)
            tok = st.prefill_tokens + st.decode_tokens
            computed.append(tok)
            per_replica.append({
                "finished": fin,
                "goodput_rps": fin / max(out["wall"], 1e-9),
                "computed_tokens": tok,
                "peak_queue_depth": rep.peak_queue_depth,
                "readbacks_per_round": (st.token_readbacks
                                        / max(st.iterations, 1)),
                "cache_hit_tokens": st.cache_hit_tokens,
                "deferred_admissions": st.deferred_admissions,
            })
            # the zero-sync invariant must survive multi-replica pumping
            assert st.token_readbacks == st.iterations, \
                f"replica {rep.index}: readbacks != iterations under {policy}"
        report = router.routing_report()
        imb = max(computed) / max(min(computed), 1)
        results[key] = {
            "finished": len(out["finished"]),
            "wall_s": out["wall"],
            "per_replica": per_replica,
            "routed": report["routed"],
            "spills": report["spills"],
            "affine_hits": report["affine_hits"],
            "directory_hit_rate": report["directory"]["hit_rate"],
            "imbalance_computed_tokens": imb,
        }
        emit(f"router/{key}/finished", len(out["finished"]), f"of {len(reqs)}")
        emit(f"router/{key}/imbalance", f"{imb:.3f}",
             "max/min per-replica computed tokens")
        emit(f"router/{key}/directory_hit_rate",
             f"{report['directory']['hit_rate']:.3f}", "")

    assert outputs["single"] == outputs["prefix_affine"] == \
        outputs["round_robin"], "routing changed greedy outputs"
    results["token_parity"] = True
    affine = results["prefix_affine"]
    rr = results["round_robin"]
    assert affine["directory_hit_rate"] > 0.5, \
        f"affine directory hit rate {affine['directory_hit_rate']:.3f} <= 0.5"
    assert affine["imbalance_computed_tokens"] < \
        rr["imbalance_computed_tokens"], \
        (f"affine imbalance {affine['imbalance_computed_tokens']:.3f} not "
         f"below round-robin {rr['imbalance_computed_tokens']:.3f}")
    emit("router/imbalance_gap",
         f"{rr['imbalance_computed_tokens'] / affine['imbalance_computed_tokens']:.3f}x",
         "round-robin / prefix-affine (higher = affinity wins)")
    write_json("router", results)
    return results


if __name__ == "__main__":
    if "--engine" in sys.argv:
        engine_comparison()
    elif "--profile-overhead" in sys.argv:
        profile_overhead()
    elif "--prefix-cache" in sys.argv:
        prefix_cache_comparison()
    elif "--spec-k" in sys.argv:
        k = int(sys.argv[sys.argv.index("--spec-k") + 1])
        speculation_comparison(spec_k=k)
    elif "--replicas" in sys.argv:
        n = int(sys.argv[sys.argv.index("--replicas") + 1])
        router_comparison(replicas=n)
    else:
        main()
