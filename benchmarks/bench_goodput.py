"""Paper Fig. 4: maximum goodput under SLO constraints.

Goodput = requests/s served with <= 1% of requests violating their SLO
(p99-style cap); the maximum is found by QPS binary search per
(model x dataset x scheduler).
"""
from __future__ import annotations

from benchmarks.common import QUICK, SCHEDULERS, emit, run_sim
from repro.serving.metrics import max_goodput

SEARCH = {
    # dataset: (lo, hi) QPS search bracket
    "sharegpt": (0.5, 16.0),
    "arxiv-v1": (0.25, 4.0),
    "arxiv-v2": (0.25, 3.0),
    "mixed-v1": (0.125, 8.0),
    "mixed-v2": (0.125, 8.0),
}


def main(quick: bool = QUICK) -> dict:
    models = ["qwen2.5-7b"] if quick else ["qwen2.5-7b", "llama3-8b"]
    datasets = ["sharegpt", "arxiv-v1", "mixed-v1"] if quick else list(SEARCH)
    duration = 60.0 if quick else 150.0
    iters = 5 if quick else 7
    results = {}
    for model in models:
        for ds in datasets:
            lo, hi = SEARCH[ds]
            base = None
            for sched in SCHEDULERS:
                def at(qps, _s=sched):
                    _, summ = run_sim(_s, model, ds, qps, duration)
                    return summ
                out = max_goodput(at, lo, hi, violation_cap=0.01, iters=iters)
                results[(model, ds, sched)] = out["qps"]
                emit(f"goodput/{model}/{ds}/{sched}", f"{out['qps']:.3f}",
                     f"viol={out['summary']['violation_rate']:.4f}")
                if sched == "sarathi-edf":
                    base = out["qps"]
                elif sched == "slidingserve" and base:
                    gain = (results[(model, ds, "slidingserve")] / max(base, 1e-9) - 1) * 100
                    emit(f"goodput_gain_vs_sarathi/{model}/{ds}", f"{gain:.1f}%",
                         "paper claims 25-111%")
    return results


if __name__ == "__main__":
    main()
