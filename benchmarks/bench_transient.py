"""Paper Fig. 6: transient overload — polarized load alternating low/high
QPS every 2 minutes over 20 minutes on mixed-v1; cumulative violations."""
from __future__ import annotations

import numpy as np

from benchmarks.common import QUICK, SCHEDULERS, emit
from repro.configs.bench_models import BENCH_MODELS
from repro.serving.costmodel import CostModel, HardwareSpec, ModelProfile
from repro.serving.metrics import cumulative_violations, summarize
from repro.serving.simulator import ServingSimulator
from repro.serving.workloads import WorkloadSpec, make_workload


def polarized_workload(cm, low_qps: float, high_qps: float, phase: float,
                       total: float, seed: int = 3):
    """Alternating low/high arrival-rate phases (paper: 1.5x peak-to-trough;
    we use the paper's stated QPS 1.0 <-> 2.5 endpoints)."""
    reqs = []
    t0 = 0.0
    idx = 0
    phase_i = 0
    while t0 < total:
        qps = high_qps if phase_i % 2 else low_qps
        wl = make_workload(WorkloadSpec("mixed-v1", qps, phase, seed=seed + phase_i), cm)
        for r in wl:
            r.rid = idx
            r.arrival += t0
            idx += 1
            reqs.append(r)
        t0 += phase
        phase_i += 1
    return reqs


def main(quick: bool = QUICK) -> dict:
    total = 600.0 if quick else 1200.0     # paper: 20 minutes
    phase = 60.0 if quick else 120.0       # paper: 2-minute phases
    cfg = BENCH_MODELS["qwen2.5-7b"]
    prof = ModelProfile.from_config(cfg)
    results = {}
    series = {}
    for sched_name, cls in SCHEDULERS.items():
        cm = CostModel(prof, HardwareSpec(chips=1), seed=7)
        wl = polarized_workload(cm, 1.0, 2.5, phase, total)
        sched = cls(max_budget=4096)
        sim = ServingSimulator(sched, cm, wl, kv_capacity_tokens=512 * 1024)
        res = sim.run()
        s = summarize(res.requests, res.duration)
        cum = cumulative_violations(res.requests, total, step=30.0)
        series[sched_name] = cum
        results[sched_name] = s
        emit(f"transient/{sched_name}/violation_rate", f"{s['violation_rate']:.4f}",
             f"n={s['n_requests']}")
        emit(f"transient/{sched_name}/final_cumulative", cum[-1][1], "")
    if "slidingserve" in results and "sarathi-edf" in results:
        red = (1 - results["slidingserve"]["violation_rate"]
               / max(results["sarathi-edf"]["violation_rate"], 1e-9)) * 100
        emit("transient/viol_reduction_vs_sarathi", f"{red:.1f}%", "paper: 30.2%")
    if "slidingserve" in results and "qoserve" in results:
        red = (1 - results["slidingserve"]["violation_rate"]
               / max(results["qoserve"]["violation_rate"], 1e-9)) * 100
        emit("transient/viol_reduction_vs_qoserve", f"{red:.1f}%", "paper: 23.7%")
    return {"summary": results, "series": series}


if __name__ == "__main__":
    main()
