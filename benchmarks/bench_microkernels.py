"""Kernel micro-benchmarks: interpret-mode correctness timing + model-layer
throughput of the jnp paths on CPU (the TPU perf path is the Pallas kernel;
this prints ref-vs-kernel agreement and per-call walltime for the record).

``--dma-overlap`` adds the fused-layout microbench: the double-buffered
decode/prefill kernels run in interpret mode against their oracles, the
partial-softmax recombine is asserted bit-exact against the full kernel, and
single-scatter vs split-scatter KV writes are timed. Results land in
``BENCH_microkernels.json`` (section ``dma_overlap``) next to the roofline
layout A/B numbers."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_bench_json


def _time(f, *args, reps=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else None
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def dma_overlap_bench() -> None:
    """Fused-layout / double-buffered-DMA microbench (interpret-mode smoke on
    CPU: correctness + shape/recombine assertions; walltime is recorded for
    the artifact but only meaningful on TPU where the ping-pong DMA actually
    overlaps compute)."""
    from repro.kernels.paged_attention.kernel import (paged_attention,
                                                      paged_attention_fused)
    from repro.kernels.paged_attention.ref import paged_attention_fused_ref
    from repro.kernels.paged_prefill_attention.kernel import (
        paged_prefill_attention_fused)
    from repro.kernels.paged_prefill_attention.ref import (
        paged_prefill_attention_fused_ref)
    from repro.kernels.ref_common import finalize_partials

    rng = np.random.default_rng(11)
    out = {"backend": jax.default_backend()}
    B, Hkv, G, D, ps, P, n = 4, 4, 2, 64, 16, 32, 8
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(Hkv, P, ps, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(Hkv, P, ps, D)), jnp.float32)
    kvp = jnp.stack([kp, vp], axis=2)
    bt = jnp.asarray(rng.integers(0, P, (B, n)), jnp.int32)
    ln = jnp.asarray([n * ps, ps, ps - 3, 77], jnp.int32)

    # decode: legacy grid-pipelined kernel vs fused double-buffered kernel
    t_old = _time(lambda: paged_attention(q, kp, vp, bt, ln, scale=0.125,
                                          interpret=True))
    t_new = _time(lambda: paged_attention_fused(q, kvp, bt, ln, scale=0.125,
                                                interpret=True))
    full = paged_attention_fused(q, kvp, bt, ln, scale=0.125, interpret=True)
    ref = paged_attention_fused_ref(q, kvp, bt, ln, scale=0.125)
    err = float(jnp.max(jnp.abs(full - ref)))
    assert full.shape == (B, Hkv * G, D), full.shape
    assert err < 2e-5, err
    # partial-softmax recombine must be bit-exact vs the full kernel
    acc, m, l = paged_attention_fused(q, kvp, bt, ln, scale=0.125,
                                      partial=True, interpret=True)
    assert acc.shape == (B, Hkv * G, D) and m.shape == l.shape == (B, Hkv * G)
    assert np.array_equal(np.asarray(finalize_partials(acc, l, q.dtype)),
                          np.asarray(full)), "partial recombine not bit-exact"
    out["decode"] = {"us_old_split": t_old, "us_fused_dma": t_new,
                     "max_err_vs_ref": err, "partial_recombine_bit_exact": True}
    emit("kernel/paged_attention_fused/us_per_call", f"{t_new:.0f}",
         f"split-legacy {t_old:.0f}us interpret")

    # ragged prefill: fused double-buffered kernel vs oracle
    R, Sq = 3, 32
    qp = jnp.asarray(rng.normal(size=(R, Sq, Hkv, G, D)), jnp.float32)
    btp = jnp.asarray(rng.integers(0, P, (R, n)), jnp.int32)
    rp = jnp.asarray([0, ps, n * ps - Sq], jnp.int32)
    lnp_ = rp + jnp.asarray([Sq, Sq - 5, Sq], jnp.int32)
    t_pref = _time(lambda: paged_prefill_attention_fused(
        qp, kvp, btp, rp, lnp_, scale=0.125, block_q=16, interpret=True))
    outp = paged_prefill_attention_fused(qp, kvp, btp, rp, lnp_, scale=0.125,
                                         block_q=16, interpret=True)
    refp = paged_prefill_attention_fused_ref(qp, kvp, btp, rp, lnp_,
                                             scale=0.125)
    q_pos = np.asarray(rp)[:, None] + np.arange(Sq)[None, :]
    valid = q_pos < np.asarray(lnp_)[:, None]
    errp = float(np.max(np.abs(np.asarray(outp)[valid]
                               - np.asarray(refp)[valid])))
    assert outp.shape == qp.shape, outp.shape
    assert errp < 2e-5, errp
    out["prefill"] = {"us_fused_dma": t_pref, "max_err_vs_ref": errp}
    emit("kernel/paged_prefill_attention_fused/us_per_call", f"{t_pref:.0f}",
         "interpret")

    # KV write: one fused scatter vs two split scatters (real CPU win too)
    from repro.models.attention import write_pages, write_pages_fused
    T = 256
    k_new = jnp.asarray(rng.normal(size=(1, T, Hkv, D)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(1, T, Hkv, D)), jnp.float32)
    slots = jnp.asarray(rng.choice(P * ps, size=T, replace=False), jnp.int32)
    f_split = jax.jit(lambda: (write_pages(kp, k_new, slots),
                               write_pages(vp, v_new, slots)))
    f_fused = jax.jit(lambda: write_pages_fused(kvp, k_new, v_new, slots))
    t_split, t_fused = _time(f_split), _time(f_fused)
    out["kv_write"] = {"us_split_two_scatters": t_split,
                       "us_fused_one_scatter": t_fused}
    emit("kernel/write_pages_fused/us_per_call", f"{t_fused:.0f}",
         f"split {t_split:.0f}us jit")
    write_bench_json("dma_overlap", out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dma-overlap", action="store_true",
                    help="run the fused-layout/double-buffered-DMA microbench "
                         "and write BENCH_microkernels.json")
    args = ap.parse_args()
    rng = np.random.default_rng(0)

    # chunked prefill attention: jnp blockwise path (the serving hot loop)
    from repro.models.attention import blockwise_attention
    B, S, Hkv, G, D = 1, 1024, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, Hkv, G, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    f = jax.jit(lambda q, k, v: blockwise_attention(q, k, v, scale=0.125,
                                                    block_q=256, block_k=256))
    us = _time(f, q, k, v)
    flops = 4 * S * S / 2 * Hkv * G * D * B
    emit("kernel/blockwise_attention_1k/us_per_call", f"{us:.0f}",
         f"{flops / us / 1e3:.1f} GFLOP/s cpu")

    # paged attention interpret-mode (correctness-path timing)
    from repro.kernels.paged_attention.kernel import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref
    qd = jnp.asarray(rng.normal(size=(4, 8, 64)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(4, 32, 16, 64)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, 32, (4, 8)), jnp.int32)
    ln = jnp.asarray([128, 96, 64, 17], jnp.int32)
    out_k = paged_attention(qd, kp, kp, bt, ln, scale=0.125, interpret=True)
    out_r = paged_attention_ref(qd, kp, kp, bt, ln, scale=0.125)
    emit("kernel/paged_attention/max_err", f"{float(jnp.max(jnp.abs(out_k - out_r))):.2e}",
         "interpret vs ref")

    from repro.kernels.mamba_scan.kernel import mamba_scan
    from repro.kernels.mamba_scan.ref import mamba_scan_ref
    x = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(2, 128, 64))) * 0.1, jnp.float32)
    Bc = jnp.asarray(rng.normal(size=(2, 128, 8)), jnp.float32)
    A = -jnp.exp(jnp.asarray(rng.normal(size=(64, 8)), jnp.float32))
    Dp = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    out_k = mamba_scan(x, dt, Bc, Bc, A, Dp, chunk=32, d_tile=32, interpret=True)
    out_r = mamba_scan_ref(x, dt, Bc, Bc, A, Dp)
    emit("kernel/mamba_scan/max_err", f"{float(jnp.max(jnp.abs(out_k - out_r))):.2e}",
         "interpret vs ref")

    from repro.kernels.mlstm_chunkwise.kernel import mlstm_chunkwise
    from repro.kernels.mlstm_chunkwise.ref import mlstm_ref
    qm = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    km = qm / np.sqrt(32)
    li = jnp.asarray(rng.normal(size=(1, 2, 128)), jnp.float32)
    lf = jax.nn.log_sigmoid(jnp.asarray(rng.normal(size=(1, 2, 128)) + 3, jnp.float32))
    out_k = mlstm_chunkwise(qm, km, qm, li, lf, chunk=64, interpret=True)
    out_r = mlstm_ref(qm, km, qm, li, lf)
    emit("kernel/mlstm_chunkwise/max_err", f"{float(jnp.max(jnp.abs(out_k - out_r))):.2e}",
         "interpret vs ref")

    if args.dma_overlap:
        dma_overlap_bench()


if __name__ == "__main__":
    main()
