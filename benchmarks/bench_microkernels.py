"""Kernel micro-benchmarks: interpret-mode correctness timing + model-layer
throughput of the jnp paths on CPU (the TPU perf path is the Pallas kernel;
this prints ref-vs-kernel agreement and per-call walltime for the record)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def _time(f, *args, reps=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else None
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def main() -> None:
    rng = np.random.default_rng(0)

    # chunked prefill attention: jnp blockwise path (the serving hot loop)
    from repro.models.attention import blockwise_attention
    B, S, Hkv, G, D = 1, 1024, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, Hkv, G, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    f = jax.jit(lambda q, k, v: blockwise_attention(q, k, v, scale=0.125,
                                                    block_q=256, block_k=256))
    us = _time(f, q, k, v)
    flops = 4 * S * S / 2 * Hkv * G * D * B
    emit("kernel/blockwise_attention_1k/us_per_call", f"{us:.0f}",
         f"{flops / us / 1e3:.1f} GFLOP/s cpu")

    # paged attention interpret-mode (correctness-path timing)
    from repro.kernels.paged_attention.kernel import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref
    qd = jnp.asarray(rng.normal(size=(4, 8, 64)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(4, 32, 16, 64)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, 32, (4, 8)), jnp.int32)
    ln = jnp.asarray([128, 96, 64, 17], jnp.int32)
    out_k = paged_attention(qd, kp, kp, bt, ln, scale=0.125, interpret=True)
    out_r = paged_attention_ref(qd, kp, kp, bt, ln, scale=0.125)
    emit("kernel/paged_attention/max_err", f"{float(jnp.max(jnp.abs(out_k - out_r))):.2e}",
         "interpret vs ref")

    from repro.kernels.mamba_scan.kernel import mamba_scan
    from repro.kernels.mamba_scan.ref import mamba_scan_ref
    x = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(2, 128, 64))) * 0.1, jnp.float32)
    Bc = jnp.asarray(rng.normal(size=(2, 128, 8)), jnp.float32)
    A = -jnp.exp(jnp.asarray(rng.normal(size=(64, 8)), jnp.float32))
    Dp = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    out_k = mamba_scan(x, dt, Bc, Bc, A, Dp, chunk=32, d_tile=32, interpret=True)
    out_r = mamba_scan_ref(x, dt, Bc, Bc, A, Dp)
    emit("kernel/mamba_scan/max_err", f"{float(jnp.max(jnp.abs(out_k - out_r))):.2e}",
         "interpret vs ref")

    from repro.kernels.mlstm_chunkwise.kernel import mlstm_chunkwise
    from repro.kernels.mlstm_chunkwise.ref import mlstm_ref
    qm = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    km = qm / np.sqrt(32)
    li = jnp.asarray(rng.normal(size=(1, 2, 128)), jnp.float32)
    lf = jax.nn.log_sigmoid(jnp.asarray(rng.normal(size=(1, 2, 128)) + 3, jnp.float32))
    out_k = mlstm_chunkwise(qm, km, qm, li, lf, chunk=64, interpret=True)
    out_r = mlstm_ref(qm, km, qm, li, lf)
    emit("kernel/mlstm_chunkwise/max_err", f"{float(jnp.max(jnp.abs(out_k - out_r))):.2e}",
         "interpret vs ref")


if __name__ == "__main__":
    main()
