"""Paper Table 4: component ablation (SC, +MLPS, +BC) on mixed-v1.

Reports optimal-load QPS gain (goodput frontier) and high-load violation
improvement, mirroring the table's two columns.
"""
from __future__ import annotations

from benchmarks.common import QUICK, emit, run_sim
from repro.serving.metrics import max_goodput

VARIANTS = [
    ("sarathi-edf", "sarathi-edf", {}),
    ("slidingserve-sc", "slidingserve",
     {"enable_mlps": False, "enable_bc": False}),
    ("slidingserve-sc-mlps", "slidingserve", {"enable_bc": False}),
    ("slidingserve-sc-mlps-bc", "slidingserve", {}),
]


def main(quick: bool = QUICK) -> dict:
    duration = 60.0 if quick else 150.0
    high_qps = 4.5
    results = {}
    prev_qps = None
    for label, sched, kw in VARIANTS:
        def at(qps, _s=sched, _k=kw):
            _, summ = run_sim(_s, "qwen2.5-7b", "mixed-v1", qps, duration,
                              sched_kwargs=_k)
            return summ
        out = max_goodput(at, 0.125, 8.0, violation_cap=0.01,
                          iters=5 if quick else 7)
        _, s_high = run_sim(sched, "qwen2.5-7b", "mixed-v1", high_qps, duration,
                            sched_kwargs=kw)
        results[label] = {"optimal_qps": out["qps"],
                          "high_load_viol": s_high["violation_rate"]}
        gain = ""
        if prev_qps:
            gain = f"gain={100 * (out['qps'] / max(prev_qps, 1e-9) - 1):.1f}%"
        emit(f"ablation/{label}/optimal_qps", f"{out['qps']:.3f}", gain)
        emit(f"ablation/{label}/high_load_viol", f"{s_high['violation_rate']:.4f}",
             f"qps={high_qps}")
        prev_qps = out["qps"]
    return results


if __name__ == "__main__":
    main()
