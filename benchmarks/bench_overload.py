"""Paper Fig. 5: latency percentiles + SLO violations under overload."""
from __future__ import annotations

from benchmarks.common import QUICK, SCHEDULERS, emit, run_sim

OVERLOAD_QPS = {
    "sharegpt": 9.0,
    "arxiv-v1": 2.6,
    "arxiv-v2": 1.8,
    "mixed-v1": 4.0,
    "mixed-v2": 3.5,
}


def main(quick: bool = QUICK) -> dict:
    datasets = ["sharegpt", "arxiv-v1", "mixed-v1"] if quick else list(OVERLOAD_QPS)
    duration = 90.0 if quick else 180.0
    results = {}
    for ds in datasets:
        qps = OVERLOAD_QPS[ds]
        base_viol = None
        for sched in SCHEDULERS:
            _, s = run_sim(sched, "qwen2.5-7b", ds, qps, duration)
            results[(ds, sched)] = s
            emit(f"overload/{ds}/{sched}/violation_rate", f"{s['violation_rate']:.4f}",
                 f"qps={qps}")
            for k in ("ttft_p50", "ttft_p95", "ttft_p99", "e2e_p50", "e2e_p95", "e2e_p99"):
                emit(f"overload/{ds}/{sched}/{k}", f"{s[k]:.3f}", "seconds")
            if sched == "qoserve":
                base_viol = s["violation_rate"]
            if sched == "slidingserve" and base_viol:
                red = (1 - s["violation_rate"] / max(base_viol, 1e-9)) * 100
                emit(f"overload/{ds}/viol_reduction_vs_qoserve", f"{red:.1f}%",
                     "paper claims 16-53% under heavy load")
    return results


if __name__ == "__main__":
    main()
