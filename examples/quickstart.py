"""Quickstart: the paper's scheduler in 60 seconds.

Builds a SlidingServe scheduler, synthesizes a ShareGPT-like workload
(paper Table 2), runs the event-driven simulator against the TPU-v5e cost
model, and prints SLO metrics vs the Sarathi-EDF baseline.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.bench_models import QWEN25_7B
from repro.core import SarathiEDFScheduler, SlidingServeScheduler
from repro.serving.costmodel import CostModel, HardwareSpec, ModelProfile
from repro.serving.metrics import summarize
from repro.serving.simulator import ServingSimulator
from repro.serving.workloads import WorkloadSpec, make_workload


def main():
    profile = ModelProfile.from_config(QWEN25_7B)
    hw = HardwareSpec(chips=1)

    for sched_cls in (SarathiEDFScheduler, SlidingServeScheduler):
        cost = CostModel(profile, hw, seed=7)
        workload = make_workload(
            WorkloadSpec(dataset="sharegpt", qps=6.0, duration=60.0, seed=1), cost)
        sched = sched_cls(max_budget=4096)
        sim = ServingSimulator(sched, cost, workload,
                               kv_capacity_tokens=512 * 1024)
        result = sim.run()
        s = summarize(result.requests, result.duration)
        print(f"{sched.name:>14}: {s['n_requests']} requests | "
              f"violations {s['violation_rate']:.1%} | "
              f"TTFT p50 {s['ttft_p50'] * 1e3:.0f}ms p99 {s['ttft_p99'] * 1e3:.0f}ms | "
              f"goodput {s['goodput_rps']:.2f} req/s")


if __name__ == "__main__":
    main()
