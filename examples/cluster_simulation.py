"""Cluster-scale serving: SLO-aware scheduling across many replicas.

Scales the paper's single-engine scheduler out: a least-loaded router with
SLO-class affinity assigns requests to N independent model replicas (each a
TP group running its own SlidingServe scheduler), mirroring how the
per-replica scheduler composes with cluster-level routing at 1000+ chips.

    PYTHONPATH=src python examples/cluster_simulation.py [--replicas 4]
"""
import argparse

import numpy as np

from repro.configs.bench_models import QWEN25_7B
from repro.core import SlidingServeScheduler
from repro.serving.costmodel import CostModel, HardwareSpec, ModelProfile
from repro.serving.metrics import summarize
from repro.serving.simulator import ServingSimulator
from repro.serving.workloads import WorkloadSpec, make_workload


def route(workload, n_replicas):
    """Least-loaded routing with SLO-class affinity: summarization goes to a
    dedicated pool when possible so long prefills don't stall dialogue."""
    buckets = [[] for _ in range(n_replicas)]
    load = [0.0] * n_replicas
    long_pool = set(range(n_replicas - max(1, n_replicas // 4), n_replicas))
    for r in sorted(workload, key=lambda r: r.arrival):
        pool = (long_pool if r.slo_class == "summarization" and n_replicas > 1
                else set(range(n_replicas)) - long_pool or set(range(n_replicas)))
        tgt = min(pool, key=lambda i: load[i])
        load[tgt] += r.prompt_len + 50 * r.max_output
        buckets[tgt].append(r)
    return buckets


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--qps", type=float, default=8.0)
    args = ap.parse_args()

    profile = ModelProfile.from_config(QWEN25_7B)
    cost = CostModel(profile, HardwareSpec(chips=1), seed=7)
    workload = make_workload(
        WorkloadSpec("mixed-v1", args.qps, duration=120.0, seed=2), cost)
    buckets = route(workload, args.replicas)

    all_reqs = []
    total_iters = 0
    for i, bucket in enumerate(buckets):
        sched = SlidingServeScheduler(max_budget=4096)
        sim = ServingSimulator(sched, CostModel(profile, HardwareSpec(chips=1), seed=i),
                               bucket, kv_capacity_tokens=512 * 1024)
        res = sim.run()
        total_iters += res.iterations
        all_reqs.extend(bucket)
        s = summarize(bucket, res.duration)
        print(f"replica {i}: {len(bucket):4d} reqs viol={s['violation_rate']:.1%} "
              f"ttft_p99={s['ttft_p99']:.2f}s")
    s = summarize(all_reqs, 120.0)
    print(f"\ncluster ({args.replicas} replicas, qps={args.qps}): "
          f"viol={s['violation_rate']:.1%} goodput={s['goodput_rps']:.2f} req/s "
          f"iters={total_iters}")


if __name__ == "__main__":
    main()
