"""Train a ~100M-param llama-style model for a few hundred steps on CPU,
with checkpoint/restart fault tolerance and (optional) int8 gradient
compression — the training-substrate end-to-end driver.

    PYTHONPATH=src python examples/train_small.py --steps 200
"""
import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import RunCtx, init_params
from repro.runtime.fault_tolerance import TrainingSupervisor
from repro.train.data import DataConfig, PackedSyntheticData
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a failure at this step (tests restart)")
    args = ap.parse_args()

    # ~100M params: a scaled-down llama3.2 family member
    cfg = dataclasses.replace(
        get_config("llama3.2-3b"),
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=32000, dtype=jnp.float32, param_dtype=jnp.float32)
    rctx = RunCtx(block_q=64, block_k=64)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n / 1e6:.1f}M params")

    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps),
        compress_grads=args.compress_grads)
    step_fn = jax.jit(make_train_step(cfg, rctx, tcfg), donate_argnums=(0, 1))
    data = PackedSyntheticData(DataConfig(cfg.vocab_size, args.seq, args.batch, seed=0))

    state = {"params": params, "train": init_train_state(cfg, params, tcfg)}

    def one_step(st, i):
        batch = {"tokens": jnp.asarray(data.batch(i))}
        p, t, m = step_fn(st["params"], st["train"], batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.2f}",
                  flush=True)
        return {"params": p, "train": t}

    with tempfile.TemporaryDirectory() as ckpt_dir:
        sup = TrainingSupervisor(ckpt_dir, save_every=50)
        fired = {"done": False}

        def fail_at(step):
            if args.fail_at and step == args.fail_at and not fired["done"]:
                fired["done"] = True
                print(f"!! injecting failure at step {step}; restoring from "
                      f"checkpoint", flush=True)
                return True
            return False

        t0 = time.time()
        state, end, restarts = sup.run(one_step, state, 0, args.steps,
                                       fail_at=fail_at)
        print(f"done: {end} steps, {restarts} restart(s), "
              f"{time.time() - t0:.0f}s wall")


if __name__ == "__main__":
    main()
