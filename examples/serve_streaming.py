"""Streaming online-serving demo: submit / stream / cancel on a live engine.

Exercises the step-based online API end to end on a reduced config (CPU):

* ``InferenceServer.submit`` mixes tenants with different SLO classes
  (interactive / standard / batch) in one paged-KV engine;
* ``handle.tokens()`` streams ids incrementally (tokens surface one round
  after dispatch — the zero-sync deferred readback);
* ``handle.cancel()`` aborts one stream mid-generation and its KV pages go
  straight back to the BlockAllocator;
* a stop-token request terminates early via the EOS check that rides the
  per-round readback.

    PYTHONPATH=src python examples/serve_streaming.py [--cache-mode paged]
    REPRO_FORCE_MESH=2x4 ... python examples/serve_streaming.py --cache-mode paged

``--mesh``/``REPRO_FORCE_MESH`` (the shared helper in ``launch/mesh.py``,
same flag as ``launch/serve.py``) runs the paged executor sharded under
jit + shard_map; everything the demo asserts — streaming, cancel, stop
tokens, one readback per round, page-leak freedom — must hold unchanged.

``--shared-prefix`` runs the radix-prefix-cache smoke instead (the CI
prefix-cache job): requests sharing one system prompt are served twice,
cache on and cache off; the run asserts a non-zero hit rate, fewer computed
prefill tokens, exact greedy-token parity between the two runs, and no page
leak — also under a forced host mesh.

``--spec-k K`` (K > 0) runs the speculative-decoding smoke instead (the CI
spec-smoke job): periodic prompts are served with n-gram drafting at K and
again at 0; the run asserts non-zero acceptance, more than one emitted
token per verify row, exact greedy-token parity between the two runs, one
readback per round, and no page leak. ``--temperature/--top-k/--sample-seed``
switch the smoke to non-greedy sampling, where the assertion becomes
same-seed determinism instead of spec-on/off parity (the sampled stream is
a function of the per-round RNG fold, which speculation legitimately
re-times).
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.launch.mesh import add_mesh_argument, make_serving_mesh
from repro.serving.server import InferenceServer


def shared_prefix_smoke(args):
    """Serve a shared-system-prompt tenant mix with the prefix cache on and
    off; assert hit rate, prefill savings, and token parity."""
    from repro.serving.workloads import multiturn_followup

    cfg = get_config(args.arch).smoke()
    rng0 = np.random.default_rng(42)
    system = rng0.integers(1, cfg.vocab_size, 80).astype(np.int32)
    suffixes = [rng0.integers(1, cfg.vocab_size, 24).astype(np.int32)
                for _ in range(4)]
    runs = {}
    for pc in (True, False):
        server = InferenceServer.build(
            cfg, cache_mode="paged",
            kv_capacity_tokens=args.kv_tokens, prefix_cache=pc,
            mesh=make_serving_mesh(args.mesh))
        core = server.core
        if pc and core.mesh is not None:
            print(core.shard_banner())
        toks = []
        # sequential submits: each request arrives after the previous one
        # prefilled, so its system prompt should match frozen pages
        for sfx in suffixes:
            h = server.submit(np.concatenate([system, sfx]),
                              slo_class="standard", max_output=5)
            toks.append(h.result())
        # one multi-turn follow-up: matches across generated tokens too
        p2 = multiturn_followup(np.concatenate([system, suffixes[0]]),
                                toks[0], np.random.default_rng(7),
                                cfg.vocab_size, turn_len=16)
        toks.append(server.submit(p2, max_output=5).result())
        ci = core.cache_info()
        runs[pc] = (toks, ci)
        assert core.stats.token_readbacks == core.stats.iterations, \
            "prefix cache broke the one-readback-per-round property"
        assert core.alloc.free_blocks == core.alloc.num_blocks, "KV leaked"
        core.alloc.check_invariants()
        print(f"prefix_cache={pc}: hit {ci['hit_tokens']}/"
              f"{ci['prompt_tokens']} prompt tokens "
              f"({ci['hit_rate']:.0%}), computed "
              f"{ci['prefill_tokens_computed']}")
    on, off = runs[True], runs[False]
    assert on[0] == off[0], "prefix cache changed greedy tokens"
    assert on[1]["hit_rate"] > 0, "shared system prompt never hit the cache"
    assert on[1]["prefill_tokens_computed"] < off[1]["prefill_tokens_computed"], \
        "cache hits did not reduce prefill work"
    print(f"token parity OK across {len(on[0])} streams; prefill tokens "
          f"{off[1]['prefill_tokens_computed']} -> "
          f"{on[1]['prefill_tokens_computed']}")


def spec_smoke(args):
    """Serve periodic prompts with speculative decoding on and off; assert
    acceptance, multi-token verify rows, greedy parity, and the one-readback
    invariant (the CI ``spec-smoke`` job)."""
    cfg = get_config(args.arch).smoke()
    rng = np.random.default_rng(11)
    prompts = []
    for _ in range(4):
        base = rng.integers(1, cfg.vocab_size, 12)
        prompts.append(np.tile(base, 32 // 12 + 1)[:32].astype(np.int32))
    sampled = args.temperature > 0
    sampling = dict(temperature=args.temperature, top_k=args.top_k,
                    sample_seed=args.sample_seed)
    # sampled mode compares two identical spec runs (determinism); greedy
    # mode compares spec_k=K against spec_k=0 (bit-identical streams)
    ks = (args.spec_k, args.spec_k) if sampled else (args.spec_k, 0)
    runs = []
    for k in ks:
        server = InferenceServer.build(
            cfg, cache_mode="paged", kv_capacity_tokens=args.kv_tokens,
            mesh=make_serving_mesh(args.mesh), spec_k=k, **sampling)
        core = server.core
        if k == ks[0] and core.mesh is not None:
            print(core.shard_banner())
        handles = [server.submit(p, slo_class="standard", max_output=6)
                   for p in prompts]
        runs.append([h.result() for h in handles])
        st = core.stats
        assert st.token_readbacks == st.iterations, \
            "speculation broke the one-readback-per-round property"
        assert core.alloc.free_blocks == core.alloc.num_blocks, "KV leaked"
        core.alloc.check_invariants()
        if k:
            si = core.spec_info()
            print(f"spec_k={k}: acceptance {si['acceptance_rate']:.0%} "
                  f"({si['accepted_tokens']}/{si['draft_tokens']} drafts), "
                  f"{si['tokens_per_verify_row']:.2f} tokens/verify row, "
                  f"{st.iterations} rounds")
            assert si["draft_tokens"] > 0, "drafter never fired"
            if not sampled:
                # a sampled stream legitimately rejects every lookup draft;
                # the acceptance bar is a greedy-mode assertion
                assert si["accepted_tokens"] > 0, \
                    "drafter never had a token accepted"
                assert si["tokens_per_verify_row"] > 1.0, \
                    "verify rows emitted no extra tokens"
    assert runs[0] == runs[1], (
        "sampled speculative run is not deterministic" if sampled
        else "speculation changed the greedy stream")
    mode = (f"temperature={args.temperature} determinism" if sampled
            else f"greedy parity (spec_k={args.spec_k} vs 0)")
    print(f"{mode} OK across {len(prompts)} streams")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--cache-mode", default="auto",
                    choices=["auto", "slot", "paged"])
    ap.add_argument("--kv-tokens", type=int, default=4096)
    ap.add_argument("--shared-prefix", action="store_true",
                    help="run the prefix-cache smoke (hit rate + parity "
                         "assertions) instead of the streaming demo")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="run the speculative-decoding smoke (acceptance + "
                         "parity assertions) with K drafted tokens per row")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="with --spec-k: sample instead of greedy decode "
                         "(asserts same-seed determinism, not parity)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--sample-seed", type=int, default=0)
    add_mesh_argument(ap)
    args = ap.parse_args()
    if args.shared_prefix:
        shared_prefix_smoke(args)
        return
    if args.spec_k > 0:
        spec_smoke(args)
        return

    cfg = get_config(args.arch).smoke()
    server = InferenceServer.build(cfg, cache_mode=args.cache_mode,
                                   max_slots=4, max_len=512,
                                   kv_capacity_tokens=args.kv_tokens,
                                   mesh=make_serving_mesh(args.mesh))
    core = server.core
    print(f"online API demo on {cfg.name} ({core.cache_mode} KV cache)")
    if core.mesh is not None:
        print(core.shard_banner())

    rng = np.random.default_rng(0)
    mk = lambda n: rng.integers(1, cfg.vocab_size, n).astype(np.int32)

    # --- three tenants in one engine -------------------------------------
    chat = server.submit(mk(48), slo_class="interactive", max_output=8)
    summ = server.submit(mk(96), slo_class="batch", max_output=12)
    spam = server.submit(mk(64), slo_class="standard", max_output=64)

    # stream the interactive request token by token
    print(f"req {chat.rid} [interactive] streaming: ", end="", flush=True)
    for tok in chat.tokens():
        print(tok, end=" ", flush=True)
    print(f"<done: {chat.finish_reason}>")

    # cancel the long-running one mid-decode; its pages free immediately
    for tok in spam.tokens():
        if len(spam.collected) >= 3:
            spam.cancel()
            break
    print(f"req {spam.rid} [standard] cancelled after "
          f"{len(spam.collected)} tokens (reason={spam.finish_reason})")

    # stop-token request: terminate when the model emits a known id.
    # Greedy decode is deterministic, so reuse the chat request's second
    # token as the stop id for an identical prompt — it must stop there.
    stop_tok = chat.collected[1]
    rng2 = np.random.default_rng(0)
    same_prompt = rng2.integers(1, cfg.vocab_size, 48).astype(np.int32)
    eos = server.submit(same_prompt, slo_class="standard", max_output=8,
                        stop_ids=(stop_tok,))
    out = eos.result()
    print(f"req {eos.rid} [stop_ids=({stop_tok},)] -> {out} "
          f"(reason={eos.finish_reason})")
    assert out == chat.collected[:2], "stop-token run diverged from greedy"

    # drain the batch tenant
    summ.result()
    print(f"req {summ.rid} [batch] -> {summ.collected}")

    st = core.stats
    print(f"iterations={st.iterations} readbacks={st.token_readbacks} "
          f"aborted={st.aborted} evictions={st.evictions} "
          f"max_concurrency={st.max_concurrency}")
    if core.cache_mode == "paged":
        assert st.token_readbacks == st.iterations, \
            "streaming frontend broke the one-readback-per-round property"
        assert core.alloc.free_blocks == core.alloc.num_blocks, \
            "KV pages leaked"
        print(f"KV pool fully released "
              f"({core.alloc.free_blocks}/{core.alloc.num_blocks} pages free)")


if __name__ == "__main__":
    main()
