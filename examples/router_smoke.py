"""Network front door smoke: HTTP/SSE server + 2-replica prefix-affine router.

The CI ``router-smoke`` job's scenario, runnable by hand:

1. launches ``python -m repro.frontend.http_server --replicas 2`` as a real
   subprocess (its own process, own engines, SIGINT-driven lifecycle);
2. replays a shared-prefix workload through the HTTP client and checks the
   SSE token streams are **bit-identical** to an in-process single-engine
   run of the same prompts (replicas share seed-0 params, so routing must
   never change greedy tokens);
3. cancels a request mid-stream over HTTP and checks it aborts server-side;
4. reads ``GET /v1/stats`` and checks the router's prefix directory took
   hits (the shared stream landed on its holder) and that every replica
   kept the one-readback-per-round zero-sync invariant;
5. sends SIGINT and checks the server drains gracefully and exits 0.

    PYTHONPATH=src JAX_PLATFORMS=cpu python examples/router_smoke.py
"""
from __future__ import annotations

import os
import re
import signal
import subprocess
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.frontend.client import EngineHttpClient  # noqa: E402
from repro.frontend.http_server import build_backend  # noqa: E402


def launch_server(replicas: int = 2) -> subprocess.Popen:
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    env["PYTHONUNBUFFERED"] = "1"
    return subprocess.Popen(
        [sys.executable, "-m", "repro.frontend.http_server",
         "--port", "0", "--replicas", str(replicas),
         "--kv-tokens", "2048", "--max-budget", "256", "--drain-s", "20"],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)


def wait_banner(proc: subprocess.Popen, deadline_s: float = 120.0) -> int:
    """Parse the 'listening on http://host:port' banner; returns the port."""
    t_end = time.perf_counter() + deadline_s
    while time.perf_counter() < t_end:
        line = proc.stdout.readline()
        if not line:
            raise RuntimeError(f"server exited early: {proc.poll()}")
        sys.stdout.write(f"[server] {line}")
        m = re.search(r"listening on http://[^:]+:(\d+)", line)
        if m:
            return int(m.group(1))
    raise TimeoutError("no listening banner")


def main() -> None:
    rng = np.random.default_rng(0)
    system = rng.integers(1, 1000, 48).tolist()
    prompts = [system + rng.integers(1, 1000, 16).tolist() for _ in range(5)]

    # in-process single-engine reference (same prompts, same seed-0 params):
    # the parity bar every HTTP/SSE stream must hit bit-for-bit
    ref_backend = build_backend(replicas=1, kv_tokens=2048, max_budget=256)
    reference = [ref_backend.submit(np.asarray(p, np.int32),
                                    max_output=5).result() for p in prompts]
    ref_backend.close()

    proc = launch_server(replicas=2)
    try:
        port = wait_banner(proc)
        cli = EngineHttpClient(port=port, timeout=180.0)
        cli.wait_ready(60.0)

        # --- SSE parity: sequential shared-prefix stream ---------------------
        # (sequential so each request's pages are committed — and in the
        # directory — before the next one routes)
        for i, p in enumerate(prompts):
            h = cli.generate(p, slo_class="interactive", max_output=5)
            toks = h.result()
            assert toks == reference[i], \
                f"prompt {i}: HTTP {toks} != in-process {reference[i]}"
            assert h.finish_reason == "length", h.finish_reason
        print(f"parity OK: {len(prompts)} SSE streams bit-identical "
              f"to the in-process engine")

        # --- mid-stream cancel over HTTP -------------------------------------
        h = cli.generate(rng.integers(1, 1000, 64).tolist(), max_output=256)
        got = []
        for tok in h.tokens():
            got.append(tok)
            if len(got) == 1:
                assert h.cancel(), "cancel reported not-live"
        assert h.aborted, f"finish_reason={h.finish_reason}"
        assert len(got) < 256, "cancel did not stop the stream"
        print(f"cancel OK: aborted mid-stream after {len(got)} tokens")

        # --- router + invariant checks over /v1/stats ------------------------
        st = cli.stats()
        routing = st["routing"]
        assert routing["policy"] == "prefix-affine"
        hit_rate = routing["directory"]["hit_rate"]
        assert hit_rate > 0, f"directory never hit: {routing['directory']}"
        assert routing["affine_hits"] >= len(prompts) - 1, routing
        for i, rep in enumerate(st["replicas"]):
            eng = rep["engine"]
            assert eng["token_readbacks"] == eng["iterations"], \
                f"replica {i}: zero-sync broken ({eng['token_readbacks']} " \
                f"readbacks / {eng['iterations']} rounds)"
        print(f"router OK: directory hit rate {hit_rate:.2f}, "
              f"routed={routing['routed']}, one readback/round per replica")

        # --- graceful drain on SIGINT ----------------------------------------
        proc.send_signal(signal.SIGINT)
        out, _ = proc.communicate(timeout=60)
        sys.stdout.write("".join(f"[server] {l}\n"
                                 for l in out.splitlines() if l))
        assert proc.returncode == 0, f"exit code {proc.returncode}"
        assert "drained" in out, "no drain report in server output"
        print("shutdown OK: SIGINT drained and exited 0")
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)
    print("ROUTER SMOKE PASSED")


if __name__ == "__main__":
    main()
