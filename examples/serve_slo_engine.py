"""End-to-end driver: serve a REAL model with batched requests.

SlidingServe schedules chunked prefill + continuous-batching decode over
actual JAX forward passes (reduced llama3.2 config on CPU; the identical loop
drives the sharded TPU step functions). Wall-clock latencies feed the online
batch-latency predictor; generated tokens are greedy-decoded.

    PYTHONPATH=src python examples/serve_slo_engine.py [--arch llama3.2-3b]
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core import SlidingServeScheduler
from repro.serving.engine import ServingEngine
from repro.serving.request import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--cache-mode", default="auto",
                    choices=["auto", "slot", "paged"])
    ap.add_argument("--kv-tokens", type=int, default=4096)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    sched = SlidingServeScheduler(max_budget=512, max_iter_time=2.0)
    engine = ServingEngine(cfg, sched, cache_mode=args.cache_mode,
                           max_slots=4, max_len=512,
                           kv_capacity_tokens=args.kv_tokens)

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, arrival=0.3 * i,
                prompt_len=int(rng.integers(16, 120)),
                max_output=int(rng.integers(4, 12)),
                ttft_slo=30.0, tbt_slo=30.0)
        for i in range(args.requests)
    ]
    print(f"serving {len(reqs)} requests on {cfg.name} "
          f"({engine.cache_mode} KV cache, reduced config, CPU)...")
    out = engine.serve(reqs, max_wall_s=240.0)
    for r in out["finished"]:
        toks = out["outputs"][r.rid]
        print(f"  req {r.rid}: prompt={r.prompt_len} ttft="
              f"{(r.first_token_time - r.arrival):.2f}s tokens={toks}")
    st = out["stats"]
    print(f"iterations={st.iterations} prefill_calls={st.prefill_calls} "
          f"decode_calls={st.decode_calls} jit_shapes={st.compiled_shapes} "
          f"max_round_calls={st.max_round_calls} "
          f"max_concurrency={st.max_concurrency} wall={out['wall']:.1f}s")
    print(f"predictor saw {engine.sched.predictor.observed} real batch latencies")


if __name__ == "__main__":
    main()
